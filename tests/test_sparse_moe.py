"""Sparse baselines (Tokens Choice / Experts Choice): routing semantics,
capacity/dropping behavior, BPR — the pathologies the paper contrasts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init


def _mk(variant, **kw):
    cfg = MoEConfig(variant=variant, num_experts=8, expert_d_ff=32,
                    top_k=2, capacity_factor=1.0, group_size=1, **kw)
    params = moe_init(jax.random.PRNGKey(0), 16, cfg)
    return cfg, params


def test_tokens_choice_shapes_and_finite():
    cfg, params = _mk("tokens_choice")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 16))
    y, m = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(m["moe_aux_loss"]) > 0  # balance + z losses active


def test_tokens_choice_no_drop_with_slack():
    cfg, params = _mk("tokens_choice")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    _, m = moe_apply(params, cfg, x)
    assert float(m["dropped_fraction"]) == 0.0


def test_tokens_choice_drops_under_tight_capacity():
    """Paper App. B: tight buffers => dropping grows with experts."""
    cfg, params = _mk("tokens_choice", bpr=False)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    _, m = moe_apply(params, cfg, x)
    assert float(m["dropped_fraction"]) > 0.0


def test_bpr_priority_keeps_high_score_tokens():
    """With BPR, the kept tokens must include the highest-gate tokens."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.5, top_k=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 16))
    logits = jnp.einsum(
        "btd,de->bte", x, params["router"]
    )
    probs = jax.nn.softmax(logits, -1)
    gate = probs.max(-1)[0]  # (t,)
    # run both and compare drop sets indirectly via output energy on the
    # top-gate token: with BPR it must be processed (nonzero output)
    y_bpr, m_bpr = moe_apply(params, cfg, x)
    t_star = int(jnp.argmax(gate))
    assert float(jnp.abs(y_bpr[0, t_star]).sum()) > 0


def test_experts_choice_capacity_exact():
    """Experts-Choice: every expert processes exactly capacity tokens."""
    cfg, params = _mk("experts_choice")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y, m = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    # some tokens unchosen (the paper's dropping phenomenon, App. B)
    assert 0.0 <= float(m["dropped_fraction"]) < 1.0


def test_batch_effects_exist_for_sparse_routing():
    """Tokens compete for capacity across the group — the SAME sequence
    can get different outputs depending on batch composition (the paper's
    motivation for per-sequence-deterministic Soft MoE)."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25, group_size=4)
    rng = jax.random.PRNGKey(3)
    x1 = jax.random.normal(rng, (4, 16, 16))
    x2 = x1.at[1:].set(jax.random.normal(jax.random.PRNGKey(4), (3, 16, 16)))
    y1, _ = moe_apply(params, cfg, x1)
    y2, _ = moe_apply(params, cfg, x2)
    # sequence 0 identical in both batches, output may differ
    diff = float(jnp.abs(y1[0] - y2[0]).max())
    assert diff > 0  # batch effect present (Soft MoE test asserts absence)
