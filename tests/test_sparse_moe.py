"""Sparse baselines (Tokens Choice / Experts Choice): routing semantics,
capacity/dropping behavior, BPR — the pathologies the paper contrasts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init


def _mk(variant, **kw):
    cfg = MoEConfig(variant=variant, num_experts=8, expert_d_ff=32,
                    top_k=2, capacity_factor=1.0, group_size=1, **kw)
    params = moe_init(jax.random.PRNGKey(0), 16, cfg)
    return cfg, params


def test_tokens_choice_shapes_and_finite():
    cfg, params = _mk("tokens_choice")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 16))
    y, m = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(m["moe_aux_loss"]) > 0  # balance + z losses active


def test_tokens_choice_no_drop_with_slack():
    cfg, params = _mk("tokens_choice")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    _, m = moe_apply(params, cfg, x)
    assert float(m["dropped_fraction"]) == 0.0


def test_tokens_choice_drops_under_tight_capacity():
    """Paper App. B: tight buffers => dropping grows with experts."""
    cfg, params = _mk("tokens_choice", bpr=False)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    _, m = moe_apply(params, cfg, x)
    assert float(m["dropped_fraction"]) > 0.0


def test_bpr_priority_keeps_high_score_tokens():
    """With BPR, the kept tokens must include the highest-gate tokens."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.5, top_k=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 16))
    logits = jnp.einsum(
        "btd,de->bte", x, params["router"]
    )
    probs = jax.nn.softmax(logits, -1)
    gate = probs.max(-1)[0]  # (t,)
    # run both and compare drop sets indirectly via output energy on the
    # top-gate token: with BPR it must be processed (nonzero output)
    y_bpr, m_bpr = moe_apply(params, cfg, x)
    t_star = int(jnp.argmax(gate))
    assert float(jnp.abs(y_bpr[0, t_star]).sum()) > 0


def test_experts_choice_capacity_exact():
    """Experts-Choice: every expert processes exactly capacity tokens."""
    cfg, params = _mk("experts_choice")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y, m = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    # some tokens unchosen (the paper's dropping phenomenon, App. B)
    assert 0.0 <= float(m["dropped_fraction"]) < 1.0


def test_batch_effects_exist_for_sparse_routing():
    """Tokens compete for capacity across the group — the SAME sequence
    can get different outputs depending on batch composition (the paper's
    motivation for per-sequence-deterministic Soft MoE)."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25, group_size=4)
    rng = jax.random.PRNGKey(3)
    x1 = jax.random.normal(rng, (4, 16, 16))
    x2 = x1.at[1:].set(jax.random.normal(jax.random.PRNGKey(4), (3, 16, 16)))
    y1, _ = moe_apply(params, cfg, x1)
    y2, _ = moe_apply(params, cfg, x2)
    # sequence 0 identical in both batches, output may differ
    diff = float(jnp.abs(y1[0] - y2[0]).max())
    assert diff > 0  # batch effect present (Soft MoE test asserts absence)


# ---------------------------------------------------------------------------
# per-row serving routing (the batch-invariant serving contract)
# ---------------------------------------------------------------------------


def test_serving_mode_routes_per_row_and_dropless():
    """Serving modes ("prefill"/"decode") must route each row alone with
    a dropless budget: row 0's output is bitwise identical solo,
    co-batched, and with different neighbors — and nothing drops even
    under a capacity_factor that bites hard in train mode."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25, group_size=4)
    rng = jax.random.PRNGKey(3)
    x1 = jax.random.normal(rng, (4, 16, 16))
    x2 = x1.at[1:].set(jax.random.normal(jax.random.PRNGKey(4), (3, 16, 16)))
    for mode in ("prefill", "decode"):
        y1, m1 = moe_apply(params, cfg, x1, mode=mode)
        y2, _ = moe_apply(params, cfg, x2, mode=mode)
        solo, _ = moe_apply(params, cfg, x1[:1], mode=mode)
        assert bool(jnp.array_equal(y1[0], y2[0])), mode
        assert bool(jnp.array_equal(y1[:1], solo)), mode
        assert float(m1["dropped_fraction"]) == 0.0


def test_serving_mode_is_chunk_invariant():
    """Per-token routing makes chunk boundaries invisible: routing a row
    whole equals routing it in pieces (the serving chunked-prefill /
    (k+1)-verify exactness at the layer level)."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 16))
    whole, _ = moe_apply(params, cfg, x, mode="decode")
    parts = jnp.concatenate(
        [moe_apply(params, cfg, x[:, a:b], mode="decode")[0]
         for a, b in ((0, 5), (5, 6), (6, 16))], axis=1)
    assert bool(jnp.array_equal(whole, parts))


def test_batch_coupled_escape_hatch_reproduces_train_routing():
    """MoEConfig.batch_coupled=True must force the old group routing in
    serving modes, bit-for-bit equal to mode="train"."""
    cfg, params = _mk("tokens_choice", bpr=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.5, group_size=4)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 16))
    y_train, _ = moe_apply(params, cfg, x, mode="train")
    hatch = dataclasses.replace(cfg, batch_coupled=True)
    y_hatch, _ = moe_apply(params, hatch, x, mode="decode")
    assert bool(jnp.array_equal(y_train, y_hatch))


def test_old_vs_new_equivalent_at_group_size_1():
    """Pin: with group_size <= 1 the refactor changes nothing the old
    path could distinguish — when capacity has slack, the coupled route
    (any bpr) equals the per-row dropless route exactly; and one-token
    (decode-shaped) calls are equal even under a tight capacity_factor
    (capacity clamps to >= 1 = the whole call)."""
    for bpr in (False, True):
        cfg, params = _mk("tokens_choice", bpr=bpr)
        slack = dataclasses.replace(cfg, capacity_factor=8.0, group_size=1)
        x = jax.random.normal(jax.random.PRNGKey(7), (3, 16, 16))
        y_old, _ = moe_apply(params, slack, x, mode="train")
        y_new, _ = moe_apply(params, slack, x, mode="decode")
        assert bool(jnp.array_equal(y_old, y_new)), f"bpr={bpr}"
        tight = dataclasses.replace(cfg, capacity_factor=0.25, group_size=1)
        x1 = jax.random.normal(jax.random.PRNGKey(8), (3, 1, 16))
        y_old1, _ = moe_apply(params, tight, x1, mode="train")
        y_new1, _ = moe_apply(params, tight, x1, mode="decode")
        assert bool(jnp.array_equal(y_old1, y_new1)), f"bpr={bpr}"


def test_dropped_fraction_rows_are_per_row():
    """Telemetry rows must not mix rows: with group_size=1 each row's
    dropped/kept stats must equal the same row's stats computed alone,
    and the scalar must be the row mean."""
    cfg, params = _mk("tokens_choice", bpr=False)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25, group_size=1)
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 32, 16))
    _, m = moe_apply(params, cfg, x, telemetry=True, mode="train")
    rows = m["telemetry"]["rows"]
    assert rows["dropped_fraction"].shape == (3,)
    assert rows["kept_fraction"].shape == (3,)
    np.testing.assert_allclose(
        float(m["dropped_fraction"]),
        float(rows["dropped_fraction"].mean()), rtol=1e-6)
    for i in range(3):
        _, mi = moe_apply(params, cfg, x[i:i + 1], telemetry=True,
                          mode="train")
        np.testing.assert_allclose(
            float(rows["dropped_fraction"][i]),
            float(mi["telemetry"]["rows"]["dropped_fraction"][0]),
            rtol=1e-6)


def test_experts_choice_serving_mode_batch_invariant():
    """Experts-choice selection is inherently cross-token; at serving it
    scopes within the row with a full budget — row outputs must be
    independent of neighbors, and nothing may go unselected."""
    cfg, params = _mk("experts_choice")
    cfg = dataclasses.replace(cfg, capacity_factor=0.5, group_size=4)
    x1 = jax.random.normal(jax.random.PRNGKey(10), (4, 16, 16))
    x2 = x1.at[1:].set(jax.random.normal(jax.random.PRNGKey(11), (3, 16, 16)))
    y1, m = moe_apply(params, cfg, x1, mode="decode", telemetry=True)
    y2, _ = moe_apply(params, cfg, x2, mode="decode")
    assert bool(jnp.array_equal(y1[0], y2[0]))
    assert float(m["dropped_fraction"]) == 0.0
    assert m["telemetry"]["rows"]["dropped_fraction"].shape == (4,)
