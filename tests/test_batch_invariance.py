"""Batch-invariant MoE serving — end-to-end enforcement of the per-row
routing contract (core/sparse_moe.py, serve/programs.py):

* solo-vs-co-batched token-for-token equality for EVERY arch in
  configs/archs.py that carries an MoE block, greedy and sampled, on
  both cache backends;
* exact chunked-prefill == whole-prompt parity on sparse-MoE archs
  (the "differs by design" caveat this refactor deleted);
* prefix caching on MoE archs with token parity;
* the `batch_coupled=True` escape hatch re-creating the old coupled
  behavior end-to-end (so the equality tests above are known to be
  non-vacuous).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_apply, lm_init
from repro.serve import Request, SamplingParams, ServeEngine

# every arch in configs/archs.py with an MoE block
MOE_ARCHS = ["deepseek-v2-lite-16b", "granite-moe-1b-a400m"]

_PARAMS = {}


def _setup(name, **moe_over):
    key = (name, tuple(sorted(moe_over.items())))
    if key not in _PARAMS:
        cfg = reduced(get_config(name))
        if moe_over:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
        _PARAMS[key] = (cfg, lm_init(jax.random.PRNGKey(0), cfg))
    return _PARAMS[key]


def _serve_target(cfg, params, prompt, fillers, sampling, backend,
                  max_new=8, max_len=64):
    """Serve `prompt` co-batched with `fillers`; return its tokens."""
    kw = {"backend": backend}
    if backend == "paged":
        kw["block_size"] = 8
    eng = ServeEngine(cfg, params, batch_size=max(1, 1 + len(fillers)),
                      max_len=max_len, **kw)
    tgt = Request(prompt=list(prompt), max_new_tokens=max_new,
                  sampling=sampling)
    reqs = [tgt] + [Request(prompt=list(f), max_new_tokens=max_new,
                            sampling=sampling) for f in fillers]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return tgt.out


@pytest.mark.parametrize("arch", MOE_ARCHS)
@pytest.mark.parametrize("backend", ["contiguous", "paged"])
@pytest.mark.parametrize("sampled", [False, True])
def test_solo_equals_cobatched(arch, backend, sampled):
    """A request's tokens are a function of the request, never of its
    batch neighbors — greedy and sampled, both backends, every MoE
    arch. Group/capacity/BPR knobs are forced to the historically
    batch-coupled worst case to prove they no longer reach serving."""
    cfg, params = _setup(arch, group_size=4, capacity_factor=0.5, bpr=True)
    sp = (SamplingParams(temperature=0.9, top_k=20, seed=7) if sampled
          else SamplingParams())
    prompt = [1, 2, 3, 4, 5]
    fillers = [[9, 8, 7], [4] * 6, [2, 4, 6, 8]]
    solo = _serve_target(cfg, params, prompt, [], sp, backend)
    cob = _serve_target(cfg, params, prompt, fillers, sp, backend)
    assert solo == cob


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_chunked_prefill_matches_dense_forward_sparse_moe(arch):
    """Chunked prefill must reproduce the dense (whole-prompt) forward
    EXACTLY on sparse-MoE archs. With capacity slack the train-mode
    forward routes identically to serving's dropless per-row path, so
    the dense reference can be lm_apply itself — the same oracle the
    dense-arch test uses."""
    cfg, params = _setup(arch, capacity_factor=8.0)
    prompt = list(range(1, 11))  # 10 tokens, chunk 4 -> left pad 2
    cur = jnp.asarray([prompt], jnp.int32)
    ref = []
    for _ in range(5):
        logits, _, _ = lm_apply(params, cfg, cur, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], 1)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      prefill_chunk=4)
    r = Request(prompt=prompt, max_new_tokens=5)
    eng.submit(r)
    eng.run()
    assert r.out == ref


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_chunk_size_invisible_under_binding_capacity(arch):
    """Even with knobs that would make per-call capacity bind hard in
    train mode, serving output is independent of the prefill chunking
    (per-token dropless routing sees no call boundary)."""
    cfg, params = _setup(arch, group_size=4, capacity_factor=0.25, bpr=True)
    prompt = list(range(3, 17))
    outs = []
    for chunk in (None, 4, 7):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          prefill_chunk=chunk)
        r = Request(prompt=list(prompt), max_new_tokens=6)
        eng.submit(r)
        eng.run()
        outs.append(r.out)
    assert outs[0] == outs[1] == outs[2]


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_prefix_cache_parity_on_moe(arch):
    """Prefix-cache hits skip prefill compute for the shared prefix; on
    MoE archs the continuation must still be token-for-token the
    no-cache engine's (per-row routing makes the suffix's routing
    independent of how many prefix tokens shared its original call)."""
    cfg, params = _setup(arch)
    shared = [7] * 12
    prompts = [shared + [i + 1] for i in range(3)]

    def run(prefix_cache):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          backend="paged", block_size=4,
                          prefix_cache=prefix_cache)
        reqs = [Request(prompt=list(p), max_new_tokens=6) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, [r.out for r in reqs]

    _, cold = run(False)
    eng, warm = run(True)
    assert warm == cold
    assert eng.backend.prefix is not None
    assert eng.backend.prefix.hits > 0  # the cache actually engaged


def test_escape_hatch_restores_batch_coupling_end_to_end():
    """batch_coupled=True must reproduce the old behavior through the
    whole engine: the same worst-case knobs that read equal above now
    make the co-batched stream diverge from the solo stream. This keeps
    the invariance tests falsifiable — if they could never fail, they
    would prove nothing."""
    cfg, params = _setup("granite-moe-1b-a400m", group_size=4,
                         capacity_factor=0.5, bpr=True, batch_coupled=True)
    sp = SamplingParams()
    prompt = [1, 2, 3, 4, 5]
    fillers = [[9, 8, 7], [4] * 6, [2, 4, 6, 8]]
    solo = _serve_target(cfg, params, prompt, [], sp, "contiguous")
    cob = _serve_target(cfg, params, prompt, fillers, sp, "contiguous")
    assert solo != cob
