"""Serving engine behaviour: wave batching, EOS, sampling, cache reuse."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import Request, ServeEngine, sample_temperature


def _engine(batch=2, **kw):
    cfg = reduced(get_config("llama3-8b"))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, ServeEngine(cfg, params, batch_size=batch, max_len=64, **kw)


def test_multi_wave_batching():
    cfg, eng = _engine(batch=2)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4) for _ in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)


def test_eos_stops_request():
    cfg, eng = _engine(batch=1)
    # force EOS on the first sampled token by making every token the eos
    first = None
    probe = Request(prompt=[1, 2, 3], max_new_tokens=8)
    eng.submit(probe)
    eng.run()
    first = probe.out[0]
    cfg2, eng2 = _engine(batch=1, eos_id=first)
    req = Request(prompt=[1, 2, 3], max_new_tokens=8)
    eng2.submit(req)
    eng2.run()
    assert req.out[0] == first
    assert len(req.out) <= 2  # stopped at (or just after) EOS


def test_temperature_sampler_runs():
    cfg, eng = _engine(
        batch=2,
        sampler=lambda r, l: sample_temperature(r, l, 1.0),
        seed=7,
    )
    reqs = [Request(prompt=[5, 6], max_new_tokens=5) for _ in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out) == 5 for r in reqs)
    assert all(
        0 <= t < cfg.vocab_size for r in reqs for t in r.out
    )


def test_variable_prompt_lengths_right_aligned():
    cfg, eng = _engine(batch=2)
    r1 = Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=3)
    r2 = Request(prompt=[7], max_new_tokens=3)
    eng.submit(r1)
    eng.submit(r2)
    eng.run()
    assert r1.done and r2.done
    assert len(r1.out) == 3 and len(r2.out) == 3
