"""Continuous-batching engine behaviour: churn, EOS retirement, chunked
prefill correctness, fixed decode shapes (zero recompiles), streaming."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import lm_apply, lm_init
from repro.serve import Request, SamplingParams, ServeEngine, WaveEngine


def _setup(name="llama3-8b"):
    cfg = reduced(get_config(name))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(batch=2, name="llama3-8b", **kw):
    cfg, params = _setup(name)
    return cfg, ServeEngine(cfg, params, batch_size=batch, max_len=64, **kw)


def test_more_requests_than_slots():
    cfg, eng = _engine(batch=2)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4) for _ in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    # identical prompts + greedy -> identical continuations, regardless of
    # which slot each request landed in or what shared its batch
    assert all(r.out == reqs[0].out for r in reqs)


def test_eos_stops_request():
    """EOS must retire the row at the very step it fires (seed-baseline
    failure: the wave engine only masked the row and kept decoding)."""
    cfg, eng = _engine(batch=1)
    probe = Request(prompt=[1, 2, 3], max_new_tokens=8)
    eng.submit(probe)
    eng.run()
    first = probe.out[0]
    cfg2, eng2 = _engine(batch=1, eos_id=first)
    req = Request(prompt=[1, 2, 3], max_new_tokens=8)
    eng2.submit(req)
    eng2.run()
    assert req.out == [first]  # retired at the step EOS fired
    assert req.done


def test_eos_frees_slot_for_queued_request():
    """The slot a retired row held is handed to the next queued request —
    total decode calls stay bounded by work, not by wave boundaries."""
    cfg, eng = _engine(batch=1)
    probe = Request(prompt=[1, 2, 3], max_new_tokens=1)
    eng.submit(probe)
    eng.run()
    eos = probe.out[0]

    cfg2, eng2 = _engine(batch=1, eos_id=eos)
    short = Request(prompt=[1, 2, 3], max_new_tokens=8)  # EOS at step 1
    longer = Request(prompt=[9, 8, 7], max_new_tokens=3)
    eng2.submit(short)
    eng2.submit(longer)
    eng2.run()
    assert short.done and short.out == [eos]
    assert longer.done and len(longer.out) == 3


def test_chunked_prefill_matches_dense_forward():
    """Prompt split into fixed chunks (left-padded first chunk) must
    reproduce the dense forward exactly on a dense arch."""
    cfg, params = _setup("llama3-8b")
    prompt = list(range(1, 11))  # 10 tokens, chunk 4 -> left pad 2
    cur = jnp.asarray([prompt], jnp.int32)
    ref = []
    for _ in range(5):
        logits, _, _ = lm_apply(params, cfg, cur, mode="train")
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        cur = jnp.concatenate([cur, jnp.asarray([[nxt]], jnp.int32)], 1)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64, prefill_chunk=4)
    r = Request(prompt=prompt, max_new_tokens=5)
    eng.submit(r)
    eng.run()
    assert r.out == ref


def test_no_decode_recompiles_under_churn():
    """The acceptance criterion: after a one-request warmup, the jit cache
    of every serving program stays FROZEN however rows churn (mixed prompt
    lengths, budgets, early retirement, slot reuse). `jit_cache_sizes`
    counts compiled signatures of every serving program, so zero growth ==
    zero recompiles."""
    cfg, eng = _engine(batch=2)
    warm = Request(prompt=[1, 2], max_new_tokens=2)
    eng.submit(warm)
    eng.run()
    sizes = eng.jit_cache_sizes()
    reqs = [
        Request(prompt=list(range(1, 2 + i)), max_new_tokens=2 + i % 5)
        for i in range(6)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    after = eng.jit_cache_sizes()
    assert after == sizes, f"serving programs recompiled: {sizes} -> {after}"


def test_variable_prompt_lengths():
    cfg, eng = _engine(batch=2)
    r1 = Request(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=3)
    r2 = Request(prompt=[7], max_new_tokens=3)
    eng.submit(r1)
    eng.submit(r2)
    eng.run()
    assert r1.done and r2.done
    assert len(r1.out) == 3 and len(r2.out) == 3


def test_streaming_callback_order():
    cfg, eng = _engine(batch=2)
    seen = []
    r = Request(prompt=[1, 2, 3], max_new_tokens=4,
                on_token=lambda req, tok: seen.append(tok))
    eng.submit(r)
    eng.run()
    assert seen == r.out and len(seen) == 4


def test_temperature_sampling_runs():
    cfg, eng = _engine(
        batch=2, default_sampling=SamplingParams(temperature=1.0, seed=7)
    )
    reqs = [Request(prompt=[5, 6], max_new_tokens=5) for _ in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out) == 5 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_submit_rejects_oversized_request():
    cfg, eng = _engine(batch=1)
    try:
        eng.submit(Request(prompt=list(range(60)), max_new_tokens=8))
    except ValueError:
        return
    raise AssertionError("expected ValueError for prompt+budget > max_len")


def test_wave_engine_still_generates():
    """The lockstep baseline (bench_serve.py) stays functional."""
    cfg, params = _setup()
    eng = WaveEngine(cfg, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert reqs[0].out == reqs[1].out == reqs[2].out


def test_continuous_matches_wave_greedy():
    """Same requests, same params: both engines produce identical greedy
    token streams (the scheduler changes *when* rows run, not *what* they
    compute)."""
    cfg, params = _setup()
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [4, 4, 4, 4]]
    outs = []
    for build in (ServeEngine, WaveEngine):
        eng = build(cfg, params, batch_size=2, max_len=64)
        reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]
