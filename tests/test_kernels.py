"""Pallas kernels vs ref.py oracle: shape/dtype sweep + gradient checks
(interpret mode on CPU; BlockSpec tiling is TPU-targeted)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init
from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.soft_moe_kernels import combine_pallas, dispatch_pallas

SHAPES = [
    (64, 128, 32),    # aligned
    (100, 256, 96),   # ragged tokens
    (196, 384, 128),  # ViT-S/16 sequence
    (256, 512, 300),  # ragged slots
    (48, 64, 8),      # tiny
]


@pytest.mark.parametrize("m,d,s", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_matches_ref(m, d, s, dtype):
    rng = jax.random.PRNGKey(m * 7 + s)
    x = jax.random.normal(rng, (m, d), dtype)
    phi = jax.random.normal(jax.random.PRNGKey(1), (d, s), jnp.float32)
    phi_n = ref.normalized_phi(phi, jnp.float32(1.3))
    want = ref.dispatch_ref(x, phi_n)
    got = dispatch_pallas(x, phi_n)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,d,s", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_matches_ref(m, d, s, dtype):
    rng = jax.random.PRNGKey(m * 13 + s)
    x = jax.random.normal(rng, (m, d), dtype)
    phi = jax.random.normal(jax.random.PRNGKey(2), (d, s), jnp.float32)
    ys = jax.random.normal(jax.random.PRNGKey(3), (s, d), dtype)
    phi_n = ref.normalized_phi(phi, jnp.float32(0.7))
    want = ref.combine_ref(x, phi_n, ys)
    got = combine_pallas(x, phi_n, ys)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_full_layer_kernel_path_matches_jnp():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=8, expert_d_ff=128,
                    slots_per_expert=2)
    params = moe_init(rng, 64, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 64))
    y0, _ = moe_apply(params, cfg, x, use_kernel=False)
    y1, _ = moe_apply(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_kernel_gradients_match_jnp():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=32)
    params = moe_init(rng, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p, use_kernel):
        y, _ = moe_apply(p, cfg, x, use_kernel=use_kernel)
        return (y**2).mean()

    g0 = jax.grad(lambda p: loss(p, False))(params)
    g1 = jax.grad(lambda p: loss(p, True))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_dispatch_under_jit_and_vmap():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 32))
    phi = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    phi_n = ref.normalized_phi(phi, 1.0)
    out = jax.jit(ops.soft_moe_dispatch)(x, phi_n)
    assert out.shape == (3, 16, 32)
    want = jax.vmap(lambda xs: ref.dispatch_ref(xs, phi_n))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
