"""Pallas kernels vs ref.py oracle: shape/dtype sweep + gradient checks
(interpret mode on CPU; BlockSpec tiling is TPU-targeted).

The backward runs through the flash-style Pallas kernels (custom_vjp in
ops.py), so the gradient tests below are kernel-vs-ref-VJP checks, not
kernel-vs-itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init
from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.soft_moe_kernels import combine_pallas, dispatch_pallas
from repro.kernels.tuning import KernelConfig, config_from_moe, default_config

SHAPES = [
    (64, 128, 32),    # aligned
    (100, 256, 96),   # ragged tokens
    (196, 384, 128),  # ViT-S/16 sequence
    (256, 512, 300),  # ragged slots
    (48, 64, 8),      # tiny
]


@pytest.mark.parametrize("m,d,s", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_matches_ref(m, d, s, dtype):
    rng = jax.random.PRNGKey(m * 7 + s)
    x = jax.random.normal(rng, (m, d), dtype)
    phi = jax.random.normal(jax.random.PRNGKey(1), (d, s), jnp.float32)
    phi_n = ref.normalized_phi(phi, jnp.float32(1.3))
    want = ref.dispatch_ref(x, phi_n)
    got = dispatch_pallas(x, phi_n)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,d,s", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_matches_ref(m, d, s, dtype):
    rng = jax.random.PRNGKey(m * 13 + s)
    x = jax.random.normal(rng, (m, d), dtype)
    phi = jax.random.normal(jax.random.PRNGKey(2), (d, s), jnp.float32)
    ys = jax.random.normal(jax.random.PRNGKey(3), (s, d), dtype)
    phi_n = ref.normalized_phi(phi, jnp.float32(0.7))
    want = ref.combine_ref(x, phi_n, ys)
    got = combine_pallas(x, phi_n, ys)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_full_layer_kernel_path_matches_jnp():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=8, expert_d_ff=128,
                    slots_per_expert=2)
    params = moe_init(rng, 64, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 64))
    y0, _ = moe_apply(params, cfg, x, use_kernel=False)
    y1, _ = moe_apply(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_kernel_gradients_match_jnp():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=32)
    params = moe_init(rng, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p, use_kernel):
        y, _ = moe_apply(p, cfg, x, use_kernel=use_kernel)
        return (y**2).mean()

    g0 = jax.grad(lambda p: loss(p, False))(params)
    g1 = jax.grad(lambda p: loss(p, True))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_dispatch_under_jit_and_vmap():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 32))
    phi = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    phi_n = ref.normalized_phi(phi, 1.0)
    out = jax.jit(ops.soft_moe_dispatch)(x, phi_n)
    assert out.shape == (3, 16, 32)
    want = jax.vmap(lambda xs: ref.dispatch_ref(xs, phi_n))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash backward: jax.grad of the kernel path vs the ref.py VJP
# ---------------------------------------------------------------------------

# (b, m, d, s): ragged tokens, ragged slots, batch > 1 through the
# batch-grid path, and a block-aligned control.
GRAD_SHAPES = [
    (1, 64, 32, 16),    # aligned, single sequence
    (2, 100, 48, 24),   # ragged tokens, batch 2
    (3, 72, 32, 150),   # ragged slots (not a block multiple), batch 3
    (2, 200, 64, 70),   # ragged both, blocks smaller than extents
]
_GCFG = KernelConfig(block_tokens=64, block_slots=64, interpret=True)


def _kernel_loss(x, phi_n, ys):
    slots, c_stats = ops.soft_moe_routing(x, phi_n, config=_GCFG)
    y = ops.soft_moe_combine(x, phi_n, ys + 0.5 * slots, c_stats=c_stats,
                             config=_GCFG)
    return (y ** 2).mean() + (slots ** 3).mean()


def _ref_loss(x, phi_n, ys):
    slots = jax.vmap(lambda xs: ref.dispatch_ref(xs, phi_n))(x)
    y = jax.vmap(
        lambda xs, yy: ref.combine_ref(xs, phi_n, yy))(x, ys + 0.5 * slots)
    return (y ** 2).mean() + (slots ** 3).mean()


@pytest.mark.parametrize("b,m,d,s", GRAD_SHAPES)
def test_flash_backward_matches_ref_vjp(b, m, d, s):
    x = jax.random.normal(jax.random.PRNGKey(b * 31 + m), (b, m, d))
    phi = jax.random.normal(jax.random.PRNGKey(7), (d, s))
    phi_n = ref.normalized_phi(phi, jnp.float32(1.1))
    ys = jax.random.normal(jax.random.PRNGKey(8), (b, s, d))
    gk = jax.grad(_kernel_loss, argnums=(0, 1, 2))(x, phi_n, ys)
    gr = jax.grad(_ref_loss, argnums=(0, 1, 2))(x, phi_n, ys)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-6)


def test_flash_backward_bf16_inputs_f32_accum():
    b, m, d, s = 2, 100, 32, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (b, m, d), jnp.bfloat16)
    phi = jax.random.normal(jax.random.PRNGKey(1), (d, s), jnp.float32)
    phi_n = ref.normalized_phi(phi, jnp.float32(0.9))
    ys = jax.random.normal(jax.random.PRNGKey(2), (b, s, d), jnp.bfloat16)
    assert _GCFG.acc() == jnp.float32  # f32 accumulation under bf16 inputs
    gk = jax.grad(lambda *a: _kernel_loss(*a).astype(jnp.float32),
                  argnums=(0, 1, 2))(x, phi_n, ys)
    gr = jax.grad(lambda *a: _ref_loss(*a).astype(jnp.float32),
                  argnums=(0, 1, 2))(x, phi_n, ys)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=5e-2, atol=5e-4,
        )


def test_combine_online_equals_stats_path():
    """Standalone combine (online softmax) == combine fed routing stats."""
    b, m, d, s = 2, 90, 32, 40
    x = jax.random.normal(jax.random.PRNGKey(0), (b, m, d))
    phi_n = ref.normalized_phi(
        jax.random.normal(jax.random.PRNGKey(1), (d, s)), jnp.float32(1.0))
    ys = jax.random.normal(jax.random.PRNGKey(2), (b, s, d))
    cfg = KernelConfig(block_tokens=32, block_slots=32, interpret=True)
    _, c_stats = ops.soft_moe_routing(x, phi_n, config=cfg)
    y_stats = ops.soft_moe_combine(x, phi_n, ys, c_stats=c_stats, config=cfg)
    y_online = ops.soft_moe_combine(x, phi_n, ys, config=cfg)
    np.testing.assert_allclose(np.asarray(y_stats), np.asarray(y_online),
                               rtol=1e-5, atol=1e-5)


def test_layer_batched_kernel_path_with_batch_grid():
    """batch > 1 flows through the single-launch batch-grid kernels."""
    cfg = MoEConfig(variant="soft", num_experts=8, expert_d_ff=64,
                    slots_per_expert=2)
    params = moe_init(jax.random.PRNGKey(0), 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 70, 48))
    y0, m0 = moe_apply(params, cfg, x, use_kernel=False)
    y1, m1 = moe_apply(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    # inspection parity: max_combine now surfaced on the kernel path too
    np.testing.assert_allclose(float(m0["max_combine"]),
                               float(m1["max_combine"]), rtol=1e-4)


def test_no_ms_materialization_in_grad_jaxpr():
    """No (m × s) logit/weight tensor exists anywhere in the jaxpr of the
    fused path's forward+backward (the jnp path does materialize them)."""
    from benchmarks.bench_kernels import check_materialization

    check_materialization(verbose=False)


# ---------------------------------------------------------------------------
# kernel-config subsystem (tuning.py)
# ---------------------------------------------------------------------------


def test_interpret_policy_is_lazy_and_overridable():
    # default: derived from the backend at call time (CPU here)
    assert KernelConfig().resolve_interpret() is True
    # explicit override wins in both directions
    assert KernelConfig(interpret=False).resolve_interpret() is False
    assert KernelConfig(interpret=True).resolve_interpret() is True
    assert ops.interpret_default() is True  # no import-time global


def test_config_from_moe_fields_and_heuristics():
    moe = MoEConfig(variant="soft", num_experts=16, expert_d_ff=64,
                    kernel_block_tokens=32, kernel_block_slots=16)
    cfg = config_from_moe(moe, m=128, d=64)
    assert (cfg.block_tokens, cfg.block_slots) == (32, 16)
    assert cfg.acc() == jnp.float32
    # 0 = auto: heuristic clamps to the problem extents / VMEM budget
    auto = default_config(m=40, d=64, s=8)
    assert auto.block_tokens <= 48 and auto.block_slots == 8
    big = default_config(m=4096, d=16384, s=4096)
    assert big.block_tokens <= 64 and big.block_slots <= 64


def test_kernel_config_threads_through_layer():
    cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=32,
                    kernel_block_tokens=16, kernel_block_slots=8)
    params = moe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 32))
    y0, _ = moe_apply(params, cfg, x, use_kernel=False)
    y1, _ = moe_apply(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# per-sequence invariant: the batch axis is a pure grid axis
# ---------------------------------------------------------------------------


def test_routing_row_independence_vs_batch1():
    """Row i of a batched routing launch must equal a batch-1 launch of
    that row BITWISE: the dispatch slots and BOTH saved softmax (max,
    denom) stats reduce only within the row. This is the kernel-level
    statement of batch-invariant serving — any cross-b reduction would
    show up here before it showed up in served tokens."""
    b, m, d, s = 3, 40, 64, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, m, d))
    phi = jax.random.normal(jax.random.PRNGKey(1), (d, s))
    phi_n = ops.normalized_phi(phi, jnp.float32(1.1))
    slots, d_stats, c_stats = ops.soft_moe_routing(x, phi_n,
                                                   with_d_stats=True)
    for i in range(b):
        s1, d1, c1 = ops.soft_moe_routing(x[i:i + 1], phi_n,
                                          with_d_stats=True)
        assert bool(jnp.array_equal(slots[i], s1[0])), f"slots row {i}"
        for full, solo, name in ((d_stats, d1, "d"), (c_stats, c1, "c")):
            assert bool(jnp.array_equal(full[0][i], solo[0][0])), \
                f"{name}_max row {i}"
            assert bool(jnp.array_equal(full[1][i], solo[1][0])), \
                f"{name}_den row {i}"


def test_combine_row_independence_vs_batch1():
    """Same contract for the combine kernel (stats path and online
    path): per-token softmax over slots never reads another row."""
    b, m, d, s = 3, 32, 64, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (b, m, d))
    ys = jax.random.normal(jax.random.PRNGKey(3), (b, s, d))
    phi = jax.random.normal(jax.random.PRNGKey(4), (d, s))
    phi_n = ops.normalized_phi(phi, jnp.float32(0.9))
    _, c_stats = ops.soft_moe_routing(x, phi_n)
    y = ops.soft_moe_combine(x, phi_n, ys, c_stats=c_stats)
    y_online = ops.soft_moe_combine(x, phi_n, ys)
    for i in range(b):
        _, c1 = ops.soft_moe_routing(x[i:i + 1], phi_n)
        y1 = ops.soft_moe_combine(x[i:i + 1], phi_n, ys[i:i + 1],
                                  c_stats=c1)
        assert bool(jnp.array_equal(y[i], y1[0])), f"stats row {i}"
        y1o = ops.soft_moe_combine(x[i:i + 1], phi_n, ys[i:i + 1])
        assert bool(jnp.array_equal(y_online[i], y1o[0])), f"online row {i}"


def test_full_soft_moe_layer_row_independence():
    """End-to-end per-row check against the single-sequence ref.py
    oracle: each row of a batched soft_moe layer (kernel AND jnp paths)
    matches the oracle applied to that row alone."""
    from repro.layers.mlp import experts_apply

    cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=32,
                    slots_per_expert=2)
    params = moe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 24, 32))
    n, p = cfg.num_experts, cfg.slots_per_expert

    def expert_fn(slots_flat):  # (S, d) -> (S, d), matching the layer
        per = slots_flat.reshape(n, p, 32)
        out = experts_apply(params["experts"], per, "silu")
        return out.reshape(n * p, 32)

    for use_kernel in (False, True):
        y, _ = moe_apply(params, cfg, x, use_kernel=use_kernel)
        for i in range(3):
            want = ref.soft_moe_ref(x[i], params["phi"].reshape(32, n * p),
                                    params["scale"], expert_fn)
            np.testing.assert_allclose(
                np.asarray(y[i]), np.asarray(want), rtol=2e-5, atol=2e-5,
                err_msg=f"row {i} use_kernel={use_kernel}")
