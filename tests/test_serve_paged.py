"""Paged backend behaviour: token-for-token parity with the contiguous
oracle across arch families, prefix-cache reuse correctness, COW on
shared-block divergence, preemption under memory pressure, zero
recompiles, and block-proportional peak memory."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import Request, ServeEngine

_PARAMS = {}


def _setup(name):
    if name not in _PARAMS:
        cfg = reduced(get_config(name))
        _PARAMS[name] = (cfg, lm_init(jax.random.PRNGKey(0), cfg))
    return _PARAMS[name]


# llama3 = dense GQA, mamba2 = pure SSM, hymba = hybrid attn+SSM,
# gemma3 = sliding-window local:global (ring layout vs paged layout)
@pytest.mark.parametrize(
    "arch", ["llama3-8b", "mamba2-370m", "hymba-1.5b", "gemma3-27b"]
)
def test_paged_matches_contiguous_greedy(arch):
    """The acceptance criterion: same params, same requests — the paged
    engine's greedy token streams are identical to the contiguous
    engine's, with requests churning through slots/blocks."""
    cfg, params = _setup(arch)
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [4] * 9, [5, 6] * 5, [2]]
    outs = []
    for kw in ({}, {"backend": "paged", "block_size": 8}):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64, **kw)
        reqs = [Request(prompt=list(p), max_new_tokens=5) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]


def test_prefix_cache_hits_and_matches_cold():
    """Requests sharing a system prompt reuse cached blocks (prefill
    starts past the shared prefix) and still produce the exact cold-path
    token streams."""
    cfg, params = _setup("llama3-8b")
    sys_p = list(range(100, 140))  # 40-token shared system prompt
    suffixes = [[1, 2, 3], [7, 8], [9]]
    paged = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        backend="paged", block_size=8)
    warm_reqs = []
    for sfx in suffixes:
        r = Request(prompt=sys_p + sfx, max_new_tokens=4)
        warm_reqs.append(r)
        paged.submit(r)
        paged.run()  # sequential: first inserts, later ones hit
    assert paged.backend.prefix.hits > 0
    cold = ServeEngine(cfg, params, batch_size=2, max_len=64)
    for i, sfx in enumerate(suffixes):
        r = Request(prompt=sys_p + sfx, max_new_tokens=4)
        cold.submit(r)
        cold.run()
        assert r.out == warm_reqs[i].out, f"suffix {i} diverged"


def test_prefix_cache_skips_prefill_chunks():
    """A prefix hit must actually skip model work: the second request's
    prefill covers only the uncached tail (start_pos > 0 measured via
    the scheduler's chunk plan)."""
    cfg, params = _setup("llama3-8b")
    sys_p = list(range(100, 132))  # 32 tokens = 4 full 8-token blocks
    eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                      backend="paged", block_size=8, prefill_chunk=8)
    eng.submit(Request(prompt=sys_p + [1, 2], max_new_tokens=2))
    eng.run()
    eng.submit(Request(prompt=sys_p + [3, 4], max_new_tokens=2))
    eng._admit()
    (entry,) = eng.sched.live.values()
    assert entry.start_pos == 32  # 4 cached blocks skipped
    assert entry.n_chunks == 1  # tail is one chunk, not five
    eng.run()


def test_paged_zero_recompiles_under_churn():
    """After a one-request warmup every paged program (decode, prefill
    chunk, block clear, sampler) keeps a frozen jit cache across mixed
    lengths, slot churn, prefix hits, and block allocation."""
    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", block_size=8)
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=3))
    eng.run()
    sizes = eng.jit_cache_sizes()
    reqs = [
        Request(prompt=[1, 2, 3] + list(range(i + 4)), max_new_tokens=2 + i)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.jit_cache_sizes() == sizes, (
        f"paged programs recompiled: {sizes} -> {eng.jit_cache_sizes()}"
    )


def test_cow_fork_divergence():
    """fork_slot shares every block of a live row; the first write on
    either side of a shared block must copy-on-write — the clone gets a
    private block with identical contents, and the parent's block is
    untouched."""
    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", block_size=8, prefix_cache=False)
    eng.submit(Request(prompt=list(range(1, 11)), max_new_tokens=8))
    eng._admit()
    while eng._do_prefill_chunk():
        pass
    be = eng.backend
    (entry,) = eng.sched.live.values()
    src = entry.slot
    clone = be.fork_slot(src)
    assert clone is not None and clone != src
    lb = entry.pos // be.block_size  # logical block the next write hits
    shared = int(be.tables[clone, lb])
    assert shared == int(be.tables[src, lb]) and be.mgr.needs_cow(shared)
    assert be.ensure_decode_block(clone, entry.pos)
    fresh = int(be.tables[clone, lb])
    assert fresh != shared, "write to a shared block did not COW"
    assert not be.mgr.needs_cow(int(be.tables[src, lb]))
    # the copied block carries identical KV content and positions
    for layer in be.cache:
        if "attn" not in layer:
            continue
        for leaf in layer["attn"].values():
            np.testing.assert_array_equal(np.asarray(leaf[shared]),
                                          np.asarray(leaf[fresh]))
    be.retire(clone)
    eng.run()


def test_preemption_under_block_pressure():
    """When decode outgrows the pool, a row is preempted (requeued, not
    corrupted) and every request still finishes with the exact greedy
    stream of an unconstrained run."""
    cfg, params = _setup("llama3-8b")

    def mk():
        return [Request(prompt=[3, 1, 4, 1, 5, 9, 2, 6],
                        max_new_tokens=12) for _ in range(2)]

    ref = mk()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    for r in ref:
        eng.submit(r)
    eng.run()

    tight = ServeEngine(cfg, params, batch_size=2, max_len=32,
                        backend="paged", block_size=4, num_blocks=7,
                        prefix_cache=False)
    reqs = mk()
    streamed = {id(r): [] for r in reqs}
    for r in reqs:
        r.on_token = lambda req, tok: streamed[id(req)].append(tok)
        tight.submit(r)
    tight.run()
    assert tight.preemptions >= 1, "pool was sized to force a preemption"
    assert [r.out for r in reqs] == [r.out for r in ref]
    # the restart replays tokens internally but must not re-stream them
    for r in reqs:
        assert streamed[id(r)] == r.out, "duplicate/missing streamed tokens"


def test_radix_eviction_during_serving():
    """A small pool under many distinct prompts keeps evicting LRU
    chains to make room; everything completes and matches the oracle."""
    cfg, params = _setup("llama3-8b")
    prompts = [[i] * 4 + list(range(100 + i, 108 + i)) for i in range(6)]
    tight = ServeEngine(cfg, params, batch_size=2, max_len=32,
                        backend="paged", block_size=4, num_blocks=10)
    oracle = ServeEngine(cfg, params, batch_size=2, max_len=32)
    outs = []
    for eng in (tight, oracle):
        reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
        for r in reqs:
            eng.submit(r)
            eng.run()  # sequential so the tree takes every insert
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]
    assert tight.backend.mgr.num_used <= 9
    assert tight.backend.mgr.high_water <= 9


def test_peak_memory_proportional_to_blocks():
    """Short prompts in a large-max_len paged pool must report peak cache
    bytes well under the contiguous num_slots x max_len reservation."""
    cfg, params = _setup("llama3-8b")
    cont = ServeEngine(cfg, params, batch_size=4, max_len=128)
    paged = ServeEngine(cfg, params, batch_size=4, max_len=128,
                        backend="paged", block_size=16)
    for eng in (cont, paged):
        reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=4)
                for _ in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run()
    assert paged.peak_cache_bytes() < cont.peak_cache_bytes() / 2


def test_paged_dirty_block_reuse_is_clean():
    """Block churn: a retired request's blocks are reused by the next
    request and must not leak stale KV into it (alloc-time pos clear)."""
    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=1, max_len=32,
                      backend="paged", block_size=4, num_blocks=9,
                      prefix_cache=False)
    # churn the pool with a different prompt first
    warm = Request(prompt=[9, 9, 9, 9, 9, 9], max_new_tokens=6)
    eng.submit(warm)
    eng.run()
    probe = Request(prompt=[1, 2, 3], max_new_tokens=5)
    eng.submit(probe)
    eng.run()
    fresh = ServeEngine(cfg, params, batch_size=1, max_len=32,
                        backend="paged", block_size=4, num_blocks=9,
                        prefix_cache=False)
    probe2 = Request(prompt=[1, 2, 3], max_new_tokens=5)
    fresh.submit(probe2)
    fresh.run()
    assert probe.out == probe2.out


def test_window_filling_prompt_admits():
    """A prompt that fills max_len exactly (max_new_tokens=0) must admit
    cleanly — position max_len never needs a block because the row
    retires on cache_full before any decode write (regression: the
    first-decode-token reservation used to overflow blocks_per_row and
    leak the slot)."""
    cfg, params = _setup("llama3-8b")
    for kw in ({}, {"backend": "paged", "block_size": 4}):
        eng = ServeEngine(cfg, params, batch_size=1, max_len=16, **kw)
        full = Request(prompt=list(range(1, 17)), max_new_tokens=0)
        eng.submit(full)
        eng.run()
        assert full.done
        # the slot is reusable afterwards (nothing leaked)
        again = Request(prompt=[1, 2, 3], max_new_tokens=4)
        eng.submit(again)
        eng.run()
        assert again.done and len(again.out) == 4


def test_paged_block_table_isolation():
    """Two concurrent rows write disjoint blocks: interleaved decode on
    one row never perturbs the other (same stream as running alone)."""
    cfg, params = _setup("llama3-8b")
    alone = ServeEngine(cfg, params, batch_size=1, max_len=64,
                        backend="paged", block_size=8)
    solo = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=6)
    alone.submit(solo)
    alone.run()
    both = ServeEngine(cfg, params, batch_size=2, max_len=64,
                       backend="paged", block_size=8)
    a = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=6)
    b = Request(prompt=[8, 8, 8, 8, 8, 8, 8, 8], max_new_tokens=6)
    both.submit(a)
    both.submit(b)
    both.run()
    assert a.out == solo.out
