"""Asyncio serving front end (serve/server.py): token parity with the
bare engine, streaming, cancellation/deadline resource release within
one tick, load shedding with retry, and the metrics surface."""
import asyncio
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    AsyncServer,
    QueueFull,
    Request,
    SamplingParams,
    ServeEngine,
    ServerConfig,
    ServeMetrics,
    ShedError,
    Watchdog,
    pool_snapshot,
)


def _setup(name="llama3-8b"):
    cfg = reduced(get_config(name))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, batch=2, **kw):
    return ServeEngine(cfg, params, batch_size=batch, max_len=64, **kw)


_SAMPLED = [
    SamplingParams(temperature=0.0),
    SamplingParams(temperature=1.0, seed=11),
    SamplingParams(temperature=0.9, top_k=8, seed=12),
    SamplingParams(temperature=1.1, top_p=0.9, seed=13),
    SamplingParams(temperature=0.0),
]


def _prompts(n):
    return [[1 + i, 2, 3 + (i % 4), 4] for i in range(n)]


def _direct_outputs(cfg, params, prompts, samplings, **kw):
    eng = _engine(cfg, params, **kw)
    reqs = [Request(prompt=list(p), max_new_tokens=6, sampling=s)
            for p, s in zip(prompts, samplings)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_async_server_token_parity_with_direct_engine(backend):
    """On a no-fault trace the async server is token-for-token the bare
    engine — greedy AND sampled rows, either backend."""
    cfg, params = _setup()
    prompts = _prompts(5)
    ref = _direct_outputs(cfg, params, prompts, _SAMPLED, backend=backend)

    async def go():
        eng = _engine(cfg, params, backend=backend)
        async with AsyncServer(eng) as srv:
            reqs = await asyncio.gather(*[
                srv.complete(p, max_new_tokens=6, sampling=s)
                for p, s in zip(prompts, _SAMPLED)
            ])
        return [r.out for r in reqs]

    assert asyncio.run(go()) == ref


def test_streaming_tokens_arrive_incrementally_and_match_final():
    cfg, params = _setup()

    async def go():
        eng = _engine(cfg, params)
        async with AsyncServer(eng) as srv:
            req = await srv.submit([1, 2, 3], max_new_tokens=5)
            seen = []
            async for tok in srv.stream(req):
                # stream yields each token after the engine commits it
                assert req.out[len(seen)] == tok
                seen.append(tok)
        return seen, req

    seen, req = asyncio.run(go())
    assert req.done and req.finish_reason in ("length", "eos")
    assert seen == req.out and len(seen) == 5


def test_cancellation_frees_all_row_resources_within_one_tick():
    """A live row's slot/blocks/refcounts return to pool the moment
    cancel lands — checked against the pool snapshot BEFORE admission,
    without any further engine tick."""
    cfg, params = _setup()
    eng = _engine(cfg, params, batch=1, backend="paged",
                  prefix_cache=False)
    baseline = pool_snapshot(eng)
    req = Request(prompt=[1, 2, 3], max_new_tokens=32)
    eng.submit(req)
    for _ in range(6):  # prefill + a few decode ticks: row is live
        eng.step()
    assert not req.done and eng.sched.live
    assert eng.cancel(req)
    assert req.done and req.finish_reason == "cancelled"
    snap = pool_snapshot(eng)  # no step() in between
    for key, want in baseline.items():
        got = snap[key]
        assert np.array_equal(got, want), (key, got, want)


def test_cancelled_queued_request_never_binds_memory():
    cfg, params = _setup()
    eng = _engine(cfg, params, batch=1)
    hog = Request(prompt=[1, 2, 3], max_new_tokens=8)
    queued = Request(prompt=[4, 5, 6], max_new_tokens=8)
    eng.submit(hog)
    eng.submit(queued)
    eng.step()  # hog binds the only slot
    assert eng.cancel(queued)
    assert queued.finish_reason == "cancelled" and queued.out == []
    eng.run()
    assert hog.done and len(hog.out) == 8  # unaffected


def test_async_cancel_mid_stream_frees_slot_for_next_request():
    cfg, params = _setup()

    async def go():
        eng = _engine(cfg, params, batch=1)
        async with AsyncServer(eng) as srv:
            req = await srv.submit([1, 2, 3], max_new_tokens=40)
            got = []
            async for tok in srv.stream(req):
                got.append(tok)
                if len(got) == 3:
                    break  # abandoning the stream cancels
            nxt = await srv.complete([7, 8, 9], max_new_tokens=4)
        return req, got, nxt

    req, got, nxt = asyncio.run(go())
    assert req.finish_reason == "cancelled" and len(got) == 3
    assert nxt.done and len(nxt.out) == 4


def test_deadline_expiry_queued_and_live():
    cfg, params = _setup()
    eng = _engine(cfg, params, batch=1)
    live = Request(prompt=[1, 2, 3], max_new_tokens=32, deadline_s=60.0)
    eng.submit(live)
    for _ in range(3):
        eng.step()  # bound and decoding, well inside its deadline
    assert eng.sched.live and not live.done
    queued = Request(prompt=[4, 5], max_new_tokens=4,
                     ttft_deadline_s=0.0)
    eng.submit(queued)
    live.t_submit -= 100.0  # force the total deadline past (no sleeps)
    eng.step()  # one tick expires both: the LIVE row aborts in place
    assert live.finish_reason == "deadline" and live.done
    assert queued.finish_reason == "deadline" and queued.out == []
    assert eng.deadline_misses == {"ttft": 1, "total": 1}
    # pool fully released without any further tick
    assert eng.backend.num_free_slots == 1 and not eng.sched.pending()


def test_scheduler_bounded_queue_rejects_explicitly():
    cfg, params = _setup()
    eng = _engine(cfg, params, max_queue=2)
    for i in range(2):
        eng.submit(Request(prompt=[1 + i], max_new_tokens=2))
    with pytest.raises(QueueFull):
        eng.submit(Request(prompt=[9], max_new_tokens=2))
    eng.run()  # the admitted two still complete
    # requeue (preemption path) bypasses the bound by design
    assert eng.sched.max_queue == 2


def test_overload_sheds_with_reason_and_counts():
    """More demand than the budget allows: excess requests shed with an
    explicit reason, admitted ones complete, counters are nonzero."""
    cfg, params = _setup()

    async def go():
        eng = _engine(cfg, params, batch=1)
        scfg = ServerConfig(max_queue=2, max_retries=0,
                            max_demand_factor=0.6)
        async with AsyncServer(eng, scfg) as srv:
            results = await asyncio.gather(*[
                srv.complete([1, 2, 3 + i], max_new_tokens=8)
                for i in range(8)
            ], return_exceptions=True)
            snap = srv.snapshot()
        return results, snap

    results, snap = asyncio.run(go())
    sheds = [r for r in results if isinstance(r, ShedError)]
    done = [r for r in results if isinstance(r, Request)]
    assert sheds and done, (sheds, done)
    assert all(r.reason in ("queue_full", "memory") for r in sheds)
    assert all(r.finish_reason == "length" for r in done)
    assert snap["sheds"] == len(sheds)
    assert snap["sheds"] == (snap.get("shed_queue_full", 0)
                             + snap.get("shed_memory", 0))
    assert snap["completed"] == len(done)


def test_shed_retry_with_backoff_eventually_admits():
    """A burst over the queue bound retries with backoff; capacity frees
    as the engine drains, so every request ultimately completes."""
    cfg, params = _setup()

    async def go():
        eng = _engine(cfg, params, batch=2)
        scfg = ServerConfig(max_queue=1, max_retries=12,
                            retry_backoff_s=0.02)
        async with AsyncServer(eng, scfg) as srv:
            results = await asyncio.gather(*[
                srv.complete([1, 2, 3 + i], max_new_tokens=4)
                for i in range(6)
            ])
            snap = srv.snapshot()
        return results, snap

    results, snap = asyncio.run(go())
    assert all(r.finish_reason == "length" for r in results)
    assert snap["shed_retries"] > 0 and snap.get("sheds", 0) == 0


def test_server_latency_metrics_observed():
    cfg, params = _setup()

    async def go():
        eng = _engine(cfg, params)
        async with AsyncServer(eng) as srv:
            await srv.complete([1, 2, 3], max_new_tokens=4)
            return srv.snapshot()

    snap = asyncio.run(go())
    for name in ("queue_time_s", "ttft_s", "latency_s"):
        assert snap[name]["count"] == 1
        assert snap[name]["p50"] >= 0.0
    assert snap["submitted"] == 1 and snap["completed"] == 1


def test_metrics_percentiles_and_merge():
    m = ServeMetrics()
    for v in range(100):
        m.observe("x", float(v))
    m.inc("a")
    m.merge_counters({"a": 7})
    snap = m.snapshot()
    assert snap["a"] == 7  # merge overwrites (external owner)
    assert snap["x"]["count"] == 100
    # Nearest-rank: the ceil(q/100 * n)-th smallest (1-indexed). For
    # 0..99, p50 is the 50th smallest = 49.0 (NOT 50.0 — the old
    # implementation was off by one) and p99 the 99th = 98.0.
    assert snap["x"]["p50"] == 49.0 and snap["x"]["p99"] == 98.0


def test_percentile_nearest_rank_small_series():
    """Regression for the nearest-rank off-by-one: pin exact values on
    tiny series where the old `round()`-based rank visibly diverged."""
    m = ServeMetrics()
    m.observe("one", 5.0)
    assert m.snapshot()["one"]["p50"] == 5.0
    assert m.snapshot()["one"]["p99"] == 5.0
    m2 = ServeMetrics()
    for v in (1.0, 2.0):
        m2.observe("two", v)
    # ceil(0.5 * 2) = 1 -> the 1st smallest, not the 2nd
    assert m2.snapshot()["two"]["p50"] == 1.0
    assert m2.snapshot()["two"]["p99"] == 2.0
    m3 = ServeMetrics()
    for v in (1.0, 2.0, 3.0):
        m3.observe("three", v)
    assert m3.snapshot()["three"]["p50"] == 2.0  # ceil(1.5) = 2nd
    assert m3.snapshot()["three"]["p99"] == 3.0
    m4 = ServeMetrics()
    for v in (10.0, 20.0, 30.0, 40.0):
        m4.observe("four", v)
    assert m4.snapshot()["four"]["p50"] == 20.0  # ceil(2.0) = 2nd
    assert m4.snapshot()["four"]["p99"] == 40.0


def test_watchdog_fires_once_per_stall_episode():
    fired = []
    wd = Watchdog(stall_s=0.02, on_stall=fired.append)
    assert not wd.beat(progressed=True, pending=True)
    time.sleep(0.03)
    assert wd.beat(progressed=False, pending=True)  # stall fires
    assert not wd.beat(progressed=False, pending=True)  # edge-triggered
    assert wd.beat(progressed=True, pending=True) is False  # rearm
    time.sleep(0.03)
    assert wd.beat(progressed=False, pending=True)
    assert wd.stalls == 2
    assert len(fired) == 2 and all(d >= 0.02 for d in fired)
    assert wd.last_stall_s == fired[-1]
    # idle (nothing pending) never stalls
    wd2 = Watchdog(stall_s=0.01)
    time.sleep(0.02)
    assert not wd2.beat(progressed=False, pending=False)


def test_watchdog_rearm_requires_progress_not_time():
    """After a stall fires, more elapsed time alone must NOT re-fire —
    only a progress beat rearms the edge trigger. And the progress beat
    resets the stall clock: an immediately-following silent beat does
    not fire until a full `stall_s` passes again."""
    wd = Watchdog(stall_s=0.02)
    time.sleep(0.03)
    assert wd.beat(progressed=False, pending=True)
    time.sleep(0.03)  # still stuck, even longer
    assert not wd.beat(progressed=False, pending=True)  # no re-fire
    assert wd.stalls == 1
    assert not wd.beat(progressed=True, pending=True)  # progress: rearm
    assert not wd.beat(progressed=False, pending=True)  # clock was reset
    time.sleep(0.03)
    assert wd.beat(progressed=False, pending=True)  # new episode fires
    assert wd.stalls == 2
