"""Cache-pool invariants: slot lifecycle, clean reuse, row isolation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import CachePool, Request, ServeEngine
from repro.serve.cache_pool import pool_row, pool_write_row


def _cfg(name="llama3-8b"):
    return reduced(get_config(name))


def test_acquire_release_cycle():
    pool = CachePool(_cfg(), num_slots=3, max_len=32)
    slots = [pool.acquire() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert pool.acquire() is None  # exhausted
    pool.release(slots[1])
    assert pool.num_free == 1
    assert pool.acquire() == slots[1]  # LIFO reuse of the hot slot


def test_acquired_slot_is_clean():
    """After a dirty row is released and re-acquired, every attention pos
    entry is -1 and the SSM state is zero."""
    cfg = _cfg("hymba-1.5b")  # has both attention and SSM caches
    pool = CachePool(cfg, num_slots=2, max_len=32)
    slot = pool.acquire()
    # dirty the row: write fake positions / state everywhere
    dirty = jax.tree_util.tree_map(lambda a: jnp.ones_like(a), pool.cache)
    pool.cache = dirty
    pool.release(slot)
    slot2 = pool.acquire()
    assert slot2 == slot
    for layer in pool.cache:
        if "attn" in layer:
            assert np.all(np.asarray(layer["attn"]["pos"][slot2]) == -1)
        if "ssm" in layer:
            assert np.all(np.asarray(layer["ssm"]["conv"][slot2]) == 0)
            assert np.all(np.asarray(layer["ssm"]["state"][slot2]) == 0)


def test_clear_does_not_touch_other_rows():
    cfg = _cfg("hymba-1.5b")
    pool = CachePool(cfg, num_slots=3, max_len=32)
    marked = jax.tree_util.tree_map(lambda a: jnp.ones_like(a), pool.cache)
    pool.cache = marked
    pool._free = [1]
    pool.acquire()  # clears row 1 only
    for layer in pool.cache:
        for group in layer.values():
            for leaf in group.values():
                arr = np.asarray(leaf)
                assert np.all(arr[0] == 1), "row 0 was touched"
                assert np.all(arr[2] == 1), "row 2 was touched"


def test_pool_row_roundtrip():
    cfg = _cfg()
    pool = CachePool(cfg, num_slots=3, max_len=16)
    marked = jax.tree_util.tree_map(
        lambda a: jnp.full_like(a, 7), pool.cache
    )
    row = pool_row(marked, 1)
    jax.tree_util.tree_map(
        lambda r, full: np.testing.assert_array_equal(
            np.asarray(r), np.asarray(full[1:2])
        ),
        row, marked,
    )
    back = pool_write_row(pool.cache, 1, row)
    for leaf, orig in zip(jax.tree_util.tree_leaves(back),
                          jax.tree_util.tree_leaves(pool.cache)):
        np.testing.assert_array_equal(np.asarray(leaf[1]), 7)
        np.testing.assert_array_equal(
            np.asarray(leaf[0]), np.asarray(orig[0])
        )


def test_slot_reuse_does_not_contaminate_new_request():
    """The acceptance test for per-row retirement: run request A in a slot,
    retire it, admit request B into the SAME slot while another row keeps
    decoding — B's output must equal B's output on a fresh engine (the
    stale KV rows A left behind are unreachable)."""
    cfg = _cfg()
    params = lm_init(jax.random.PRNGKey(0), cfg)

    # fresh-engine reference for B
    ref_eng = ServeEngine(cfg, params, batch_size=1, max_len=64)
    b_ref = Request(prompt=[9, 8, 7, 6], max_new_tokens=5)
    ref_eng.submit(b_ref)
    ref_eng.run()

    # batch=1 pool: A (long, different content) then B reuses A's slot
    eng = ServeEngine(cfg, params, batch_size=1, max_len=64)
    a = Request(prompt=list(range(1, 30)), max_new_tokens=6)
    b = Request(prompt=[9, 8, 7, 6], max_new_tokens=5)
    eng.submit(a)
    eng.submit(b)
    eng.run()
    assert a.done and b.done
    assert b.out == b_ref.out

    # same again but B decodes NEXT TO a live neighbour in a 2-slot pool
    eng2 = ServeEngine(cfg, params, batch_size=2, max_len=64)
    filler = Request(prompt=[3, 3, 3], max_new_tokens=12)
    a2 = Request(prompt=list(range(1, 30)), max_new_tokens=2)
    b2 = Request(prompt=[9, 8, 7, 6], max_new_tokens=5)
    eng2.submit(filler)
    eng2.submit(a2)
    eng2.submit(b2)  # queued until a2 retires, reuses a2's slot
    eng2.run()
    assert b2.out == b_ref.out


def test_ssm_state_scrubbed_on_reuse():
    """Same contamination check on a recurrent-state arch (no position
    masking protects stale SSM state — reuse must scrub it)."""
    cfg = _cfg("mamba2-370m")
    params = lm_init(jax.random.PRNGKey(0), cfg)
    ref_eng = ServeEngine(cfg, params, batch_size=1, max_len=64)
    b_ref = Request(prompt=[5, 6, 7], max_new_tokens=4)
    ref_eng.submit(b_ref)
    ref_eng.run()

    eng = ServeEngine(cfg, params, batch_size=1, max_len=64)
    a = Request(prompt=list(range(20, 40)), max_new_tokens=6)
    b = Request(prompt=[5, 6, 7], max_new_tokens=4)
    eng.submit(a)
    eng.submit(b)
    eng.run()
    assert b.out == b_ref.out
