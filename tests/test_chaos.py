"""Chaos property test: seeded random interleavings of admission,
preemption, cancellation, deadline expiry, poisoning, pool exhaustion
and retirement must leave the block pool indistinguishable from a fresh
engine — no leaked slots, blocks, refcounts, tables, or pending
speculative state — with every request in a defined terminal state."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    GarbageDrafter,
    ServeEngine,
    SpecConfig,
    pool_snapshot,
    run_chaos,
)


def _setup(name="llama3-8b"):
    cfg = reduced(get_config(name))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _assert_snapshot_equal(got: dict, want: dict):
    assert got.keys() == want.keys()
    for key in want:
        assert np.array_equal(got[key], want[key]), (
            f"{key}: {got[key]!r} != {want[key]!r}"
        )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_paged_pool_matches_fresh_engine(seed):
    """Paged backend, prefix cache OFF so the check is exact: the
    post-chaos pool state must EQUAL a fresh engine's, field by field."""
    cfg, params = _setup()

    def build():
        return ServeEngine(cfg, params, batch_size=2, max_len=64,
                           backend="paged", prefix_cache=False,
                           max_queue=6)

    fresh = pool_snapshot(build())
    eng = build()
    stats = run_chaos(eng, n_requests=14, seed=seed)
    _assert_snapshot_equal(pool_snapshot(eng), fresh)
    # the storm actually exercised abnormal paths
    assert stats["cancellations"] + stats.get("finish_deadline", 0) > 0


def test_chaos_paged_with_prefix_cache_leak_free():
    """With the radix tree ON, tree-retained blocks are legitimate;
    run_chaos's leak check flushes the tree and then demands exact
    pool emptiness."""
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", prefix_cache=True, max_queue=6)
    run_chaos(eng, n_requests=12, seed=4)
    assert eng.backend.mgr.num_used == 0  # flushed + leak-free


def test_chaos_contiguous_backend():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      max_queue=6)
    stats = run_chaos(eng, n_requests=12, seed=5)
    assert sorted(eng.backend.pool._free) == [0, 1]
    assert stats["steps"] > 0


def test_chaos_speculative_with_garbage_drafter():
    """Spec decoding under chaos: pending-token state and burst
    reservations must unwind through cancellations/poisonings too."""
    cfg, params = _setup()
    eng = ServeEngine(
        cfg, params, batch_size=2, max_len=64, backend="paged",
        prefix_cache=False, max_queue=6,
        spec=SpecConfig(drafter=GarbageDrafter(cfg.vocab_size, seed=0),
                        disable_after_rejects=2),
    )
    run_chaos(eng, n_requests=10, seed=6)
    assert (eng._spec._pending < 0).all()


def test_chaos_is_deterministic_in_seed():
    """Same seed + config => identical terminal states (the reproducer
    contract a chaos failure depends on)."""
    cfg, params = _setup()

    def run(seed):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          backend="paged", prefix_cache=False,
                          max_queue=6)
        stats = run_chaos(eng, n_requests=10, seed=seed)
        stats.pop("steps", None)
        return stats

    assert run(7) == run(7)
