"""Sharding rules: expected specs per param family, divisibility fallback,
logical-axis resolution, and (in a subprocess with 8 fake devices) the
compressed cross-pod gradient reduce."""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.distributed import ShardingOptions, param_specs
from repro.models import lm_init


def _specs_for(name, opts=None):
    cfg = reduced(get_config(name))
    params = jax.eval_shape(lambda r: lm_init(r, cfg), jax.random.PRNGKey(0))
    # fsdp_min_size=0: reduced-config params are tiny; tests assert the
    # rule structure, not the size heuristic
    opts = opts or ShardingOptions(fsdp_min_size=0)
    return param_specs(params, opts), cfg


def _find(specs, *path):
    node = specs
    for p in path:
        node = node[p]
    return node


def test_attention_and_mlp_rules():
    specs, _ = _specs_for("llama3-8b")
    seg0 = specs["segments"][0]
    # stacked layer axis never sharded; heads over model; FSDP over data
    assert _find(seg0, "attn", "wq") == (None, "data", "model", None)
    assert _find(seg0, "attn", "wo") == (None, "model", None, "data")
    assert _find(seg0, "mlp", "w_gate") == (None, "data", "model")
    assert _find(seg0, "mlp", "w_down") == (None, "model", "data")
    assert _find(specs, "embed", "table") == ("model", "data")
    assert _find(seg0, "norm1", "scale") == (None, None)


def test_expert_parallel_rules():
    specs, cfg = _specs_for("granite-moe-1b-a400m")
    moe_seg = specs["segments"][1]  # reduced() moves MoE to second half
    assert _find(moe_seg, "moe", "experts", "w_gate") == (
        None, "model", "data", None
    )
    assert _find(moe_seg, "moe", "router") == (None, "data", None)


def test_soft_moe_phi_rule():
    specs, _ = _specs_for("llama3-8b+soft")
    moe_seg = specs["segments"][1]  # second_half segment
    assert _find(moe_seg, "moe", "phi") == (None, "data", "model", None)
    assert _find(moe_seg, "moe", "scale") == (None,)


def test_fsdp_off():
    specs, _ = _specs_for(
        "llama3-8b", ShardingOptions(fsdp=False, fsdp_min_size=0)
    )
    seg0 = specs["segments"][0]
    assert _find(seg0, "mlp", "w_gate") == (None, None, "model")


def test_tp_off():
    specs, _ = _specs_for(
        "llama3-8b", ShardingOptions(tensor_parallel=False,
                                     expert_parallel=False,
                                     fsdp_min_size=0)
    )
    seg0 = specs["segments"][0]
    assert _find(seg0, "mlp", "w_gate") == (None, "data", None)


def test_divisibility_fallback():
    """hymba has 25 heads — not divisible by a 16-wide model axis; the
    sharding must fall back to replicated on that axis, not crash."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.distributed.sharding import tree_shardings, ShardingOptions
from repro.models import lm_init
cfg = get_config("hymba-1.5b")
params = jax.eval_shape(lambda r: lm_init(r, cfg), jax.random.PRNGKey(0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = tree_shardings(mesh, params, ShardingOptions())
wq = sh["segments"][0]["attn"]["wq"]
# 25 heads % 4 != 0 -> replicated on model axis
assert "model" not in str(wq.spec), wq.spec
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=".",
    )
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_compressed_pod_reduce_subprocess():
    """int8+EF pod all-reduce == exact mean within quantization error
    (8 fake devices: 2 pods x 2 data x 2 model)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.optim import ef_state_init, pod_allreduce_compressed, pod_allreduce_mean

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
# different gradient per pod: shard over pod so each pod holds its own
specs = {"w": P("pod", None)}
gs = {"w": jax.device_put(g["w"], NamedSharding(mesh, P("pod", None)))}
err = ef_state_init(gs)
exact = pod_allreduce_mean(gs, mesh, specs)
approx, new_err = pod_allreduce_compressed(gs, err, mesh, specs)
d = float(jnp.abs(exact["w"] - approx["w"]).max())
scale = float(jnp.abs(gs["w"]).max())
assert d < 0.02 * scale + 1e-6, (d, scale)
# error feedback state is nonzero (residual carried)
assert float(jnp.abs(new_err["w"]).max()) > 0
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=".",
    )
    assert "OK" in r.stdout, (r.stdout, r.stderr[-3000:])

