"""Observability surface (serve/tracing.py + serve/exporter.py): span
timelines are complete and consistent, tracing changes neither a token
nor a compiled program, the flight recorder captures per-tick state and
dumps on watchdog stalls, and /metrics round-trips through a strict
Prometheus text-format parser."""
import asyncio
import json

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    AsyncServer,
    FaultInjector,
    FlightRecorder,
    ProgramTimer,
    Request,
    SamplingParams,
    ServeEngine,
    ServeMetrics,
    ServerConfig,
    SpecConfig,
    collect_engine_metrics,
    parse_prometheus,
    render_prometheus,
    render_timeline,
    timeline,
    validate_timeline,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3-8b"))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


_SAMPLED = [
    SamplingParams(temperature=0.0),
    SamplingParams(temperature=1.0, seed=21),
    SamplingParams(temperature=0.9, top_k=8, seed=22),
    SamplingParams(temperature=1.1, top_p=0.9, seed=23),
    SamplingParams(temperature=0.0),
]


def _run_engine(cfg, params, backend, trace, spec=None):
    eng = ServeEngine(
        cfg, params, batch_size=2, max_len=64, backend=backend,
        spec=spec, trace=trace, flight_recorder=64 if trace else 0,
    )
    reqs = [
        Request(prompt=[1 + i, 2, 3 + (i % 4), 4], max_new_tokens=6,
                sampling=s)
        for i, s in enumerate(_SAMPLED)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, reqs


# -- tracing: parity + zero-recompile ---------------------------------------


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_tracing_bit_identical_tokens_and_zero_recompile(setup, backend):
    """Tracing + flight recorder are host-side only: same tokens (greedy
    AND sampled rows) and the exact same jit-cache sizes as untraced."""
    cfg, params = setup
    eng_off, reqs_off = _run_engine(cfg, params, backend, trace=False)
    eng_on, reqs_on = _run_engine(cfg, params, backend, trace=True)
    assert [r.out for r in reqs_on] == [r.out for r in reqs_off]
    assert eng_on.jit_cache_sizes() == eng_off.jit_cache_sizes()
    # untraced requests carry no spans at all (zero overhead path)
    assert all(r.spans is None for r in reqs_off)
    for r in reqs_on:
        validate_timeline(r)


def test_timeline_structure_and_derived_durations(setup):
    cfg, params = setup
    _, reqs = _run_engine(cfg, params, "contiguous", trace=True)
    tl = timeline(reqs[0])
    assert tl["spans"][0]["kind"] == "submitted"
    assert tl["spans"][0]["t"] == 0.0
    assert tl["spans"][-1]["kind"] == "retired"
    assert tl["spans"][-1]["reason"] == reqs[0].finish_reason
    assert tl["n_tokens"] == len(reqs[0].out) == 6
    kinds = [s["kind"] for s in tl["spans"]]
    assert "admitted" in kinds and "prefill_chunk" in kinds
    assert kinds.count("decode_tick") == 6
    assert 0.0 <= tl["queue_s"] <= tl["total_s"]
    assert tl["ttft_s"] > 0.0
    ts = [s["t"] for s in tl["spans"]]
    assert ts == sorted(ts)


def test_render_timeline_text_gantt(setup):
    cfg, params = setup
    _, reqs = _run_engine(cfg, params, "contiguous", trace=True)
    out = render_timeline(reqs, width=40)
    lines = out.splitlines()
    assert len(lines) == 1 + len(reqs)
    assert "Q queued" in lines[0]
    for i, (line, r) in enumerate(zip(lines[1:], reqs)):
        assert f"req {i:>3}" in line
        assert r.finish_reason in line
        assert "D" in line  # every request decoded at least one token
    assert render_timeline([]) == "(no traced requests)"


def test_spec_decode_spans_account_for_every_token(setup):
    """With speculative decoding the committed-token accounting runs
    through spec_burst spans — validate_timeline still balances."""
    cfg, params = setup
    eng, reqs = _run_engine(cfg, params, "paged", trace=True,
                            spec=SpecConfig(k=3))
    for r in reqs:
        validate_timeline(r)
    bursts = [
        attrs for r in reqs for _, kind, attrs in r.spans
        if kind == "spec_burst"
    ]
    assert bursts, "speculative run recorded no spec_burst spans"
    assert all(0 <= b["accepted"] <= b["drafted"] for b in bursts)


def test_shed_request_timeline_via_async_server(setup):
    """Admission-control sheds never reach engine.submit — the server
    opens + closes their timeline so every terminal request has one."""
    cfg, params = setup

    async def go():
        eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                          trace=True)
        scfg = ServerConfig(max_queue=1, max_retries=0,
                            max_demand_factor=0.5)
        async with AsyncServer(eng, scfg) as srv:
            results = await asyncio.gather(*[
                srv.complete([1, 2, 3 + i], max_new_tokens=6)
                for i in range(8)
            ], return_exceptions=True)
        return eng, results

    eng, results = asyncio.run(go())
    assert any(isinstance(r, Exception) for r in results)
    # the tracer saw every shed (sheds raise, so count via the tracer)
    shed_timelines = eng.tracer.started - sum(
        1 for r in results if isinstance(r, Request))
    assert shed_timelines > 0


# -- flight recorder ---------------------------------------------------------


def test_flight_recorder_ring_bounds_and_dump(tmp_path):
    rec = FlightRecorder(capacity=4)
    for i in range(10):
        rec.record({"tick": i})
    assert rec.ticks == 10
    recs = rec.records()
    assert len(recs) == 4 and recs[0]["tick"] == 6  # oldest evicted
    path = tmp_path / "dump.json"
    out = rec.dump("test_reason", path=str(path))
    assert rec.dumps == 1 and rec.last_dump is out
    assert rec.last_dump_path == str(path)
    data = json.loads(path.read_text())
    assert data["reason"] == "test_reason"
    assert data["ticks_seen"] == 10 and data["capacity"] == 4
    assert [r["tick"] for r in data["records"]] == [6, 7, 8, 9]
    assert "tick" in rec.render(2)


def test_flight_recorder_records_tick_schema(setup):
    """Every tick record carries occupancy, program timings, and the
    jit-cache sizes the zero-recompile contract is audited with."""
    cfg, params = setup
    eng, _ = _run_engine(cfg, params, "paged", trace=True)
    recs = eng.recorder.records()
    assert recs and eng.recorder.ticks == eng.ticks
    for r in recs:
        for key in ("tick", "wall_s", "queued", "live", "emitted",
                    "admitted", "jit_cache_sizes", "programs",
                    "blocks_free", "blocks_used", "slots_free"):
            assert key in r, f"tick record missing {key!r}"
    # ProgramTimer accounting reached the records: some tick decoded
    assert any(r["programs"].get("decode", {}).get("calls", 0) > 0
               for r in recs)
    assert any(r["programs"].get("prefill_chunk", {}).get("calls", 0) > 0
               for r in recs)
    # and the timers themselves accumulated lifetime totals
    assert eng._timers["decode"].calls > 0
    assert eng._timers["decode"].total_s > 0.0


def test_program_timer_transparent_wrapper():
    class Fn:
        bound_attr = 41

        def __call__(self, x):
            return x + 1

        def _cache_size(self):
            return 3

    t = ProgramTimer("f", Fn())
    assert t(1) == 2 and t(2) == 3
    assert t.calls == 2 and t.total_s >= 0.0
    tick = t.take_tick()
    assert tick["calls"] == 2
    assert t.take_tick()["calls"] == 0  # drained
    assert t.calls == 2  # lifetime total survives the drain
    # attribute passthrough: jit-cache introspection is unchanged
    assert t._cache_size() == 3 and t.bound_attr == 41


# -- metrics + exporter ------------------------------------------------------


def test_collect_engine_metrics_overwrites_across_snapshots():
    """Engine counters are externally owned: repeated collection must
    overwrite, never double-count."""

    class _Stub:
        def __init__(self):
            self.preemptions = 3

        def robustness_stats(self):
            return {"preemptions": self.preemptions, "kernel_fallbacks": 1}

    m = ServeMetrics()
    stub = _Stub()
    collect_engine_metrics(stub, m)
    collect_engine_metrics(stub, m)
    assert m.counters["preemptions"] == 3  # NOT 6
    assert m.counters["kernel_fallbacks"] == 1
    stub.preemptions = 5
    collect_engine_metrics(stub, m)
    assert m.counters["preemptions"] == 5


def test_exporter_round_trip():
    m = ServeMetrics()
    m.inc("sheds", 3)
    m.inc("deadline_misses_total", 2)  # name already ends in _total
    obs = (0.0005, 0.02, 0.3, 7.0, 120.0)  # incl. one past the last bound
    for v in obs:
        m.observe("latency_s", v)
    info = {"arch": 'we"ird\\la\nbel', "block_size": 16, "spec": "off"}
    text = render_prometheus(m, info=info)
    parsed = parse_prometheus(text)
    assert parsed["counters"]["repro_serve_sheds_total"] == 3
    # single _total suffix, not doubled
    assert parsed["counters"]["repro_serve_deadline_misses_total"] == 2
    assert "repro_serve_deadline_misses_total_total" not in parsed["counters"]
    h = parsed["histograms"]["repro_serve_latency_s"]
    assert h["count"] == len(obs)
    assert abs(h["sum"] - sum(obs)) < 1e-9
    # cumulative buckets: +Inf == count, counts non-decreasing
    cums = [c for _, c in h["buckets"]]
    assert cums == sorted(cums) and cums[-1] == len(obs)
    # label escaping survives the round trip exactly
    labels, value = parsed["gauges"]["repro_serve_engine_info"]
    assert value == 1.0
    assert labels["arch"] == 'we"ird\\la\nbel'
    assert labels["block_size"] == "16" and labels["spec"] == "off"


def test_exporter_parser_rejects_malformed_lines():
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line{\n")
    with pytest.raises(ValueError):
        parse_prometheus("# random comment\n")
    with pytest.raises(ValueError):
        parse_prometheus('m_bucket{le="0.1" 3\n')  # unclosed label set
    with pytest.raises(ValueError):
        parse_prometheus("m 1\n\nm2 2\n")  # blank line inside body
    # broken histogram invariants are caught even when lines parse
    bad = ('h_bucket{le="0.1"} 5\nh_bucket{le="+Inf"} 3\n'
           "h_sum 1.0\nh_count 3\n")
    with pytest.raises(AssertionError):
        parse_prometheus(bad)


def test_exporter_renders_live_server_surface(setup):
    cfg, params = setup

    async def go():
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          backend="paged", trace=True)
        async with AsyncServer(eng) as srv:
            await srv.complete([1, 2, 3], max_new_tokens=4)
            return srv.metrics_text()

    parsed = parse_prometheus(asyncio.run(go()))
    assert parsed["counters"]["repro_serve_completed_total"] == 1
    assert parsed["histograms"]["repro_serve_ttft_s"]["count"] == 1
    labels, _ = parsed["gauges"]["repro_serve_engine_info"]
    assert labels["backend"] == "paged" and labels["trace"] == "on"


# -- HTTP endpoints ----------------------------------------------------------


async def _get(host, port, path):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: _\r\n\r\n".encode("ascii"))
    await writer.drain()
    raw = await reader.read()
    writer.close()
    head, _, body = raw.partition(b"\r\n\r\n")
    head_lines = head.decode("latin-1").split("\r\n")
    status = int(head_lines[0].split()[1])
    headers = dict(
        line.split(": ", 1) for line in head_lines[1:] if ": " in line
    )
    return status, headers, body.decode("utf-8")


def test_http_metrics_and_healthz_endpoints(setup):
    cfg, params = setup

    async def go():
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          trace=True, flight_recorder=16)
        scfg = ServerConfig(metrics_port=0)  # ephemeral port
        async with AsyncServer(eng, scfg) as srv:
            await srv.complete([1, 2, 3], max_new_tokens=4)
            host, port = srv.metrics_addr
            metrics = await _get(host, port, "/metrics")
            health = await _get(host, port, "/healthz")
            missing = await _get(host, port, "/nope")
        return metrics, health, missing

    metrics, health, missing = asyncio.run(go())
    status, headers, body = metrics
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain; version=0.0.4")
    parsed = parse_prometheus(body)  # strict: every line must validate
    assert parsed["counters"]["repro_serve_completed_total"] == 1
    assert "repro_serve_engine_info" in parsed["gauges"]
    status, headers, body = health
    assert status == 200
    assert headers["Content-Type"] == "application/json"
    h = json.loads(body)
    assert h["status"] == "ok" and h["pump_alive"]
    assert h["open_streams"] == 0 and h["watchdog_stalls"] == 0
    assert missing[0] == 404


# -- watchdog stall -> series + recorder dump --------------------------------


def test_watchdog_stall_observes_series_and_dumps_recorder(
        setup, tmp_path):
    """Pool exhaustion with pending work: the watchdog fires, the stall
    duration lands in the watchdog_stall_s series, and the engine's
    flight recorder dumps to dump_dir for the post-mortem."""
    cfg, params = setup

    async def go():
        eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                          backend="paged", prefix_cache=False,
                          trace=True, flight_recorder=32)
        inj = FaultInjector(eng, seed=0)
        scfg = ServerConfig(watchdog_stall_s=0.05,
                            dump_dir=str(tmp_path))
        async with AsyncServer(eng, scfg) as srv:
            inj.hold_blocks()  # nothing can admit: pending + no progress
            task = asyncio.create_task(
                srv.complete([1, 2, 3], max_new_tokens=2))
            for _ in range(400):
                await asyncio.sleep(0.01)
                if srv.watchdog.stalls:
                    break
            inj.release_blocks()  # un-wedge: the request must complete
            req = await task
            snap = srv.snapshot()
        inj.detach()
        return eng, req, snap

    eng, req, snap = asyncio.run(go())
    assert snap["watchdog_stalls"] >= 1
    assert snap["watchdog_stall_s"]["count"] >= 1
    assert snap["watchdog_stall_s"]["p50"] >= 0.05
    assert req.done and req.finish_reason in ("length", "eos")
    validate_timeline(req)
    # the dump was written to dump_dir and is loadable JSON
    assert eng.recorder.dumps >= 1
    assert eng.recorder.last_dump["reason"] == "watchdog_stall"
    dumps = sorted(tmp_path.glob("flight_watchdog_stall_*.json"))
    assert dumps, "no flight-recorder dump file written"
    data = json.loads(dumps[0].read_text())
    assert data["reason"] == "watchdog_stall"
    assert isinstance(data["records"], list)
