"""Soft MoE core: faithfulness to the paper's Algorithm 1 + 2, and its
structural properties (balance, no dropping, determinism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init, soft_moe_weights
from repro.core.soft_moe import soft_moe_apply
from repro.layers.mlp import experts_apply


def paper_algorithm_1(X, Phi, experts_params, act="silu", scale=1.0):
    """Verbatim transcription of the paper's Algorithm 1 + the Algorithm 2
    L2 normalization (single sequence)."""

    def l2_normalize(x, axis, eps=1e-6):
        norm = jnp.sqrt(jnp.square(x).sum(axis=axis, keepdims=True))
        return x * jnp.reciprocal(norm + eps)

    Xn = l2_normalize(X, axis=1)
    Phin = scale * l2_normalize(Phi, axis=0)
    logits = jnp.einsum("md,dnp->mnp", Xn, Phin)
    D = jax.nn.softmax(logits, axis=(0,))
    m, n, p = logits.shape
    C = jax.nn.softmax(logits.reshape(m, n * p), axis=-1).reshape(m, n, p)
    Xs = jnp.einsum("md,mnp->npd", X, D)
    Ys = experts_apply(experts_params, Xs.reshape(n, p, -1).reshape(n, p, X.shape[1]), act)
    Y = jnp.einsum("npd,mnp->md", Ys.reshape(n, p, X.shape[1]), C)
    return Y


@pytest.fixture
def setup():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=8, expert_d_ff=64,
                    slots_per_expert=2)
    params = moe_init(rng, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 32))
    return cfg, params, x


def test_matches_paper_algorithm(setup):
    cfg, params, x = setup
    y, _ = soft_moe_apply(params, cfg, x.astype(jnp.float32))
    for b in range(x.shape[0]):
        y_ref = paper_algorithm_1(
            x[b].astype(jnp.float32), params["phi"], params["experts"],
            scale=params["scale"],
        )
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)


def test_dispatch_weights_normalized_over_tokens(setup):
    cfg, params, x = setup
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    # D: softmax over tokens (per slot); C: softmax over slots (per token)
    np.testing.assert_allclose(np.asarray(d_w.sum(axis=1)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(c_w.sum(axis=(2, 3))), 1.0, rtol=1e-5
    )


def test_no_token_dropping(setup):
    """Every token contributes strictly positive weight to every slot —
    the paper's 'immune to token dropping' property."""
    cfg, params, x = setup
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    assert bool((d_w > 0).all())
    assert bool((c_w > 0).all())


def test_balanced_by_construction(setup):
    """Every slot receives total dispatch weight exactly 1 — no expert
    imbalance regardless of input."""
    cfg, params, x = setup
    d_w, _ = soft_moe_weights(x, params["phi"], params["scale"])
    per_slot = d_w.sum(axis=1)  # (b, n, p)
    np.testing.assert_allclose(np.asarray(per_slot), 1.0, rtol=1e-5)


def test_per_sequence_determinism(setup):
    """Output for a sequence is independent of what else is in the batch
    (paper §2.2) — unlike capacity-constrained sparse routers."""
    cfg, params, x = setup
    y_full, _ = soft_moe_apply(params, cfg, x)
    y_single, _ = soft_moe_apply(params, cfg, x[:1])
    np.testing.assert_allclose(
        np.asarray(y_full[0]), np.asarray(y_single[0]), rtol=2e-4, atol=2e-4
    )


def test_fully_differentiable(setup):
    cfg, params, x = setup

    def loss(p):
        y, _ = soft_moe_apply(p, cfg, x)
        return (y**2).mean()

    grads = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # routing params get gradient from every token (paper: dense updates)
    assert float(jnp.abs(grads["phi"]).sum()) > 0
    assert float(jnp.abs(grads["scale"])) >= 0


def test_slot_count_governs_cost_not_experts():
    """Same total slots => same slot tensor shape regardless of experts."""
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (2, 16, 32))
    for n, p in [(8, 2), (16, 1), (4, 4)]:
        cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=64,
                        slots_per_expert=p)
        params = moe_init(rng, 32, cfg)
        y, _ = soft_moe_apply(params, cfg, x)
        assert y.shape == x.shape


def test_shared_experts():
    rng = jax.random.PRNGKey(0)
    cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=32,
                    num_shared_experts=2)
    params = moe_init(rng, 16, cfg)
    x = jax.random.normal(rng, (2, 8, 16))
    y, _ = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_l2_norm_bounds_logits():
    """With Algorithm 2 normalization, |logits| <= scale — the softmax
    cannot collapse as d grows (paper App. E)."""
    rng = jax.random.PRNGKey(0)
    for d in [64, 512, 4096]:
        cfg = MoEConfig(variant="soft", num_experts=4, expert_d_ff=16)
        params = moe_init(rng, d, cfg)
        x = 100.0 * jax.random.normal(rng, (1, 8, d))  # wild input scale
        d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
        # max weight bounded away from 1 (uniform-ish at init)
        assert float(d_w.max()) < 0.9
        assert float(c_w.max()) < 0.9
