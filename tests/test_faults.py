"""Fault injection (serve/faults.py): every injected fault class must
end in a DEFINED terminal state — correct finish_reason, no leaked
slots/blocks/refcounts — and degradations must never change served
tokens (kernel fallback, drafter faults) beyond the poisoned row."""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    FaultInjector,
    FlakyDrafter,
    GarbageDrafter,
    Request,
    ServeEngine,
    SpecConfig,
    assert_leak_free,
)


def _setup(name="llama3-8b"):
    cfg = reduced(get_config(name))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, batch=2, **kw):
    return ServeEngine(cfg, params, batch_size=batch, max_len=64, **kw)


def _reqs(n, max_new=6):
    return [Request(prompt=[1 + i, 2, 3], max_new_tokens=max_new)
            for i in range(n)]


def _clean_outputs(cfg, params, n, max_new=6, **kw):
    eng = _engine(cfg, params, **kw)
    reqs = _reqs(n, max_new)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.out for r in reqs]


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_nan_logits_retire_only_the_poisoned_row(backend):
    """NaN at model call k: that row ends finish_reason="error"; every
    other row's stream is bit-identical to the fault-free run."""
    cfg, params = _setup()
    clean = _clean_outputs(cfg, params, 2, max_new=8, backend=backend)
    eng = _engine(cfg, params, backend=backend)
    inj = FaultInjector(eng)
    reqs = _reqs(2, max_new=8)
    for r in reqs:
        eng.submit(r)
    while (len(eng.sched.live) < 2
           or not all(e.state == "decode"
                      for e in eng.sched.live.values())):
        eng.step()
    victim_slot = next(s for s, e in eng.sched.live.items()
                       if e.req is reqs[0])
    inj.poison_logits(victim_slot, after_calls=2)
    eng.run()
    assert reqs[0].finish_reason == "error"
    assert len(reqs[0].out) < 8  # retired early, not padded with junk
    assert reqs[1].finish_reason == "length"
    assert reqs[1].out == clean[1]  # bystander row untouched
    assert eng.nonfinite_retired == 1
    inj.detach()
    assert_leak_free(eng)


def test_kernel_failure_falls_back_to_gather_bit_exactly():
    """A raising Pallas program flips the backend to the jnp gather
    oracle permanently; outputs are the kernel run's, serving never
    drops a request."""
    cfg, params = _setup()
    clean = _clean_outputs(cfg, params, 3, backend="paged")
    eng = _engine(cfg, params, backend="paged")
    assert eng.backend.use_kernel
    inj = FaultInjector(eng)
    inj.inject_kernel_failure()
    reqs = _reqs(3)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert not eng.backend.use_kernel
    assert eng.backend.kernel_fallbacks == 1
    assert [r.out for r in reqs] == clean
    assert all(r.finish_reason == "length" for r in reqs)
    assert eng.robustness_stats()["kernel_fallbacks"] == 1
    # the rebuilt programs keep serving (no second failure path)
    more = _reqs(2)
    for r in more:
        eng.submit(r)
    eng.run()
    assert all(r.finish_reason == "length" for r in more)
    inj.detach()
    assert_leak_free(eng)


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_pool_exhaustion_stalls_admission_then_recovers(backend):
    cfg, params = _setup()
    eng = _engine(cfg, params, backend=backend, prefix_cache=False)
    inj = FaultInjector(eng)
    held = inj.hold_blocks()  # pin the whole pool
    assert held > 0
    reqs = _reqs(2)
    for r in reqs:
        eng.submit(r)
    for _ in range(5):
        eng.step()
    assert all(not r.done for r in reqs)  # stalled, not crashed/dropped
    assert not eng.sched.live  # nothing admitted into a starved pool
    inj.release_blocks()
    eng.run()
    assert all(r.finish_reason == "length" for r in reqs)
    inj.detach()
    assert_leak_free(eng)


def test_garbage_drafter_disables_rows_without_changing_tokens():
    """An out-of-range-junk drafter costs acceptance, never correctness:
    outputs stay token-for-token the baseline's, and the per-row
    kill-switch turns drafting off after the reject streak."""
    cfg, params = _setup()
    clean = _clean_outputs(cfg, params, 2, max_new=10)
    eng = _engine(cfg, params, spec=SpecConfig(
        drafter=GarbageDrafter(cfg.vocab_size, seed=3),
        disable_after_rejects=2,
    ))
    reqs = _reqs(2, max_new=10)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [r.out for r in reqs] == clean
    assert eng._spec.rows_disabled >= 1
    assert eng.robustness_stats()["spec_rows_disabled"] >= 1
    assert_leak_free(eng)


def test_flaky_drafter_errors_counted_and_contained():
    cfg, params = _setup()
    clean = _clean_outputs(cfg, params, 2, max_new=8)
    eng = _engine(cfg, params, spec=SpecConfig(
        drafter=FlakyDrafter(ok_calls=1), max_drafter_errors=2,
    ))
    reqs = _reqs(2, max_new=8)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [r.out for r in reqs] == clean
    assert eng._spec.drafter_errors > 0
    assert eng._spec.rows_disabled >= 1  # disabled after repeated raises
    assert all(r.finish_reason == "length" for r in reqs)
    assert_leak_free(eng)


def test_latency_spike_is_injected_not_fatal():
    cfg, params = _setup()
    eng = _engine(cfg, params)
    inj = FaultInjector(eng)
    inj.latency_spike(0.01, after_calls=1)
    reqs = _reqs(2)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert inj.latency_injected == 1
    assert all(r.finish_reason == "length" for r in reqs)
    inj.detach()
    assert_leak_free(eng)


def test_detach_restores_pristine_backend():
    cfg, params = _setup()
    eng = _engine(cfg, params)
    orig_decode = eng.backend.decode
    inj = FaultInjector(eng)
    assert eng.backend.decode is not orig_decode
    inj.hold_blocks(1)
    inj.detach()
    assert eng.backend.decode == orig_decode
    assert eng.backend.num_free_slots == 2  # held slot released
