"""Training substrate: optimizer, schedules, microbatching, checkpointing,
trainer fault-tolerance (resume, straggler watchdog, preemption)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import SyntheticImages, SyntheticLM
from repro.models import build_model
from repro.optim import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    schedule_lr,
)
from repro.train import StragglerWatchdog, Trainer, TrainerConfig, make_train_step
from repro.train.step import init_train_state


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = OptimizerConfig(peak_lr=0.3, schedule="constant", warmup_steps=0,
                          weight_decay=0.0, total_steps=10**9,
                          cooldown_steps=1)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(g, opt, params, cfg, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_schedules():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          cooldown_steps=20, schedule="cosine")
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1.0
    assert lrs[-1] == pytest.approx(0.0, abs=1e-6)  # cooldown tail


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced(get_config("qwen2-0.5b"))
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(0), init)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8)
    batch = data.batch(0)
    ocfg = OptimizerConfig(peak_lr=1e-2, schedule="constant",
                           warmup_steps=0, total_steps=10**9,
                           cooldown_steps=1, grad_clip_norm=1e9)
    s1, m1 = make_train_step(loss_fn, ocfg, microbatches=1)(state, batch)
    s2, m2 = make_train_step(loss_fn, ocfg, microbatches=4)(state, batch)
    # Same data => same mean loss and same accumulated gradient (compare
    # the first Adam moment, mu = (1-b1)·g after one step; comparing
    # post-update params is ill-conditioned — Adam's normalized update is
    # sign-like for near-zero gradients).
    assert float(m1["total_loss"]) == pytest.approx(
        float(m2["total_loss"]), rel=1e-3
    )
    g1 = jax.tree_util.tree_leaves(s1["opt"]["mu"])
    g2 = jax.tree_util.tree_leaves(s2["opt"]["mu"])
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=5e-4)


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4),
                {"c": jnp.zeros((2, 2), jnp.bfloat16)}]}
        for step in (10, 20, 30):
            mgr.save(step, tree)
        assert mgr.latest_step() == 30
        assert len(os.listdir(d)) == 2  # keep-N GC
        step, restored = mgr.restore_latest(tree)
        assert step == 30
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        tree = {"w": jnp.ones((128, 128))}
        mgr.save_async(1, tree)
        mgr.wait()
        assert mgr.latest_step() == 1
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.ones((5,))})


def test_trainer_resume_and_loss_decreases():
    cfg = reduced(get_config("qwen2-0.5b"))
    init, loss_fn, _ = build_model(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=24, batch_size=8)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(total_steps=20, checkpoint_every=10,
                           checkpoint_dir=d, log_every=5)
        oc = OptimizerConfig(peak_lr=3e-3, warmup_steps=2, total_steps=20,
                             cooldown_steps=2, schedule="constant")
        tr = Trainer(tc, loss_fn, init, oc, data)
        tr.run(jax.random.PRNGKey(0))
        losses = [m["total_loss"] for m in tr.metrics_history]
        assert losses[-1] < losses[0]
        # resume continues from the checkpoint, not from scratch
        tc2 = TrainerConfig(total_steps=25, checkpoint_every=10,
                            checkpoint_dir=d, log_every=5)
        tr2 = Trainer(tc2, loss_fn, init, oc, data)
        tr2.run(jax.random.PRNGKey(0))
        assert tr2.metrics_history[0]["step"] >= 20


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=2.0)
    for _ in range(10):
        assert not wd.observe(0, 1.0)
    assert wd.observe(10, 5.0)  # 5x EWMA -> straggler
    assert len(wd.events) == 1
    # EWMA not polluted by the straggler
    assert abs(wd.ewma - 1.0) < 1e-6


def test_data_pipeline_determinism_and_host_sharding():
    d1 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=8, seed=3)
    d2 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=8, seed=3)
    np.testing.assert_array_equal(
        np.asarray(d1.batch(7)["tokens"]), np.asarray(d2.batch(7)["tokens"])
    )
    # different steps differ
    assert (np.asarray(d1.batch(1)["tokens"]) !=
            np.asarray(d1.batch(2)["tokens"])).any()
    # host sharding: two hosts see different slices of the same step
    h0 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=8, host_id=0,
                     num_hosts=2)
    h1 = SyntheticLM(vocab_size=100, seq_len=8, batch_size=8, host_id=1,
                     num_hosts=2)
    assert h0.batch(0)["tokens"].shape[0] == 4
    assert (np.asarray(h0.batch(0)["tokens"]) !=
            np.asarray(h1.batch(0)["tokens"])).any()


def test_synthetic_images_learnable():
    d = SyntheticImages(num_patches=4, patch_dim=16, batch_size=16,
                        num_classes=10)
    b = d.batch(0)
    assert b["patches"].shape == (16, 4, 16)
    assert set(np.asarray(b["labels"])) <= set(range(10))
