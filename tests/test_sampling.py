"""Batched per-request sampler suite (serve/sampling.py)."""
import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import (
    SamplingParams,
    _filtered_logits,
    sample_tokens,
    spec_accept_tokens,
    stack_params,
)


def _call(logits, params_list, step=0):
    sp = stack_params(params_list)
    steps = np.full((len(params_list),), step, np.int32)
    return np.asarray(sample_tokens(
        jnp.asarray(logits, jnp.float32), sp["temperature"], sp["top_k"],
        sp["top_p"], sp["seed"], steps,
    ))


def test_temperature_zero_degenerates_to_greedy():
    rng = np.random.RandomState(0)
    logits = rng.randn(4, 32).astype(np.float32)
    toks = _call(logits, [SamplingParams(temperature=0.0, seed=i)
                          for i in range(4)])
    np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_top_k_respected_per_request_in_mixed_batch():
    """Row 0: top-1 at high temperature (must pick the argmax); row 1:
    top-k disabled; row 2: greedy. One batched call, three behaviours."""
    rng = np.random.RandomState(1)
    logits = rng.randn(3, 64).astype(np.float32)
    params = [
        SamplingParams(temperature=5.0, top_k=1, seed=11),
        SamplingParams(temperature=1.0, seed=12),
        SamplingParams(temperature=0.0),
    ]
    for step in range(20):
        toks = _call(logits, params, step=step)
        assert toks[0] == logits[0].argmax()  # top-1 == argmax despite temp
        assert toks[2] == logits[2].argmax()
        assert 0 <= toks[1] < 64


def test_top_k_limits_support():
    """With top_k=k, only the k largest logits can ever be sampled."""
    rng = np.random.RandomState(2)
    logits = rng.randn(2, 32).astype(np.float32)
    k = 5
    allowed = [set(np.argsort(-logits[b])[:k]) for b in range(2)]
    params = [SamplingParams(temperature=3.0, top_k=k, seed=b)
              for b in range(2)]
    for step in range(50):
        toks = _call(logits, params, step=step)
        for b in range(2):
            assert toks[b] in allowed[b], (b, toks[b])


def test_top_p_limits_support():
    """A spiked distribution with top_p=0.5 must only ever sample the
    spike (its prob ~1 exceeds the nucleus alone)."""
    logits = np.zeros((2, 16), np.float32)
    logits[:, 3] = 10.0  # p(3) ~ 0.9998
    params = [SamplingParams(temperature=1.0, top_p=0.5, seed=b)
              for b in range(2)]
    for step in range(20):
        toks = _call(logits, params, step=step)
        assert (toks == 3).all()


def test_top_p_one_keeps_full_support():
    """top_p=1.0 must not mask anything: over many draws from a uniform
    distribution, more than one token appears."""
    logits = np.zeros((1, 8), np.float32)
    params = [SamplingParams(temperature=1.0, top_p=1.0, seed=0)]
    seen = {int(_call(logits, params, step=s)[0]) for s in range(40)}
    assert len(seen) > 1


def test_seeds_reproducible_and_batch_independent():
    """Row i's stream depends only on (seed_i, step) — not on batch
    position or on what other rows are doing."""
    rng = np.random.RandomState(3)
    row = rng.randn(1, 32).astype(np.float32)
    p = SamplingParams(temperature=1.0, seed=42)

    solo = [int(_call(row, [p], step=s)[0]) for s in range(8)]
    # same request in slot 2 of a 4-row batch with unrelated neighbours
    batch_logits = np.concatenate(
        [rng.randn(2, 32).astype(np.float32), row,
         rng.randn(1, 32).astype(np.float32)], 0
    )
    others = [SamplingParams(temperature=0.7, top_k=3, seed=7),
              SamplingParams(temperature=0.0),
              p,
              SamplingParams(temperature=1.2, top_p=0.8, seed=9)]
    batched = [int(_call(batch_logits, others, step=s)[2])
               for s in range(8)]
    assert solo == batched
    # and a different seed gives a different stream
    p2 = SamplingParams(temperature=1.0, seed=43)
    other = [int(_call(row, [p2], step=s)[0]) for s in range(8)]
    assert solo != other


def test_top_p_zero_degenerates_to_top1():
    """top_p=0 must still keep the rank-0 token sampleable."""
    rng = np.random.RandomState(4)
    logits = rng.randn(2, 16).astype(np.float32)
    params = [SamplingParams(temperature=2.0, top_p=0.0, seed=b)
              for b in range(2)]
    for step in range(10):
        toks = _call(logits, params, step=step)
        np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_filters_compose():
    """top_k and top_p both active: support is the intersection."""
    logits = np.zeros((1, 16), np.float32)
    logits[0, :4] = np.array([5.0, 4.9, 4.8, 4.7])
    # top_k=2 keeps {0,1}; top_p tiny keeps {0}; intersection {0}
    params = [SamplingParams(temperature=1.0, top_k=2, top_p=0.05, seed=0)]
    for step in range(20):
        assert int(_call(logits, params, step=step)[0]) == 0


# ---------------------------------------------------------------------------
# nucleus-filter hardening (regression: peaked logits, HF-reference parity)
# ---------------------------------------------------------------------------


def test_top_p_below_peak_keeps_argmax():
    """Regression: when top_p is SMALLER than the single largest token
    probability (peaked logits), the nucleus mask must still keep the
    argmax lane — an all-masked row would hand categorical an all--inf
    distribution. Sweep the pathological corner across temperatures and
    peak strengths."""
    for peak in (5.0, 10.0, 30.0, 100.0):
        for temp in (0.25, 1.0, 4.0):
            for top_p in (1e-6, 0.01, 0.3):
                logits = np.zeros((2, 8), np.float32)
                logits[0, 3] = peak
                logits[1, 5] = peak
                params = [SamplingParams(temperature=temp, top_p=top_p,
                                         seed=b) for b in range(2)]
                toks = _call(logits, params)
                np.testing.assert_array_equal(
                    toks, [3, 5], err_msg=f"{peak=} {temp=} {top_p=}"
                )


def _hf_reference_mask(logits, temperature, top_k, top_p):
    """Scalar HF-style reference: temperature scale, keep the top-k
    logits, then keep the smallest descending-prob prefix whose mass
    reaches top_p (always at least one token), renormalizing after the
    top-k step. Returns the boolean support of one row."""
    scaled = logits / max(temperature, 1e-6)
    keep = np.ones_like(scaled, bool)
    if top_k > 0:
        thr = np.sort(scaled)[::-1][min(top_k, len(scaled)) - 1]
        keep &= scaled >= thr
    if top_p < 1.0:
        z = np.where(keep, scaled, -np.inf)
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        order = np.argsort(-p, kind="stable")
        cum = 0.0
        nucleus = np.zeros_like(keep)
        for i in order:
            nucleus[i] = True
            cum += p[i]
            if cum >= top_p:
                break
        keep &= nucleus
    return keep


def test_topk_topp_composition_matches_scalar_reference():
    """The vectorized filters' support must equal the scalar HF-style
    reference on random batches across the parameter grid."""
    rng = np.random.RandomState(7)
    for trial in range(5):
        logits = (rng.randn(6, 24) * rng.uniform(0.5, 4)).astype(np.float32)
        temps = rng.uniform(0.2, 3.0, size=6).astype(np.float32)
        ks = rng.choice([0, 1, 3, 8, 24], size=6).astype(np.int32)
        ps = rng.choice([0.05, 0.3, 0.7, 0.95, 1.0], size=6).astype(
            np.float32)
        masked = np.asarray(_filtered_logits(
            jnp.asarray(logits), jnp.asarray(temps), jnp.asarray(ks),
            jnp.asarray(ps),
        ))
        got = np.isfinite(masked)
        for b in range(6):
            want = _hf_reference_mask(logits[b], float(temps[b]),
                                      int(ks[b]), float(ps[b]))
            np.testing.assert_array_equal(
                got[b], want,
                err_msg=f"{trial=} {b=} k={ks[b]} p={ps[b]} t={temps[b]}",
            )


# ---------------------------------------------------------------------------
# speculative accept/resample (serve/spec_decode.py's device half)
# ---------------------------------------------------------------------------


def _accept(logits, drafts, n_draft, temp, top_k=0, top_p=1.0, seed=0,
            step=0):
    b = logits.shape[0]
    n_acc, toks = spec_accept_tokens(
        jnp.asarray(logits, jnp.float32), jnp.asarray(drafts, jnp.int32),
        np.full((b,), n_draft, np.int32), np.full((b,), temp, np.float32),
        np.full((b,), top_k, np.int32), np.full((b,), top_p, np.float32),
        np.full((b,), seed, np.int32), np.full((b,), step, np.int32),
    )
    return np.asarray(n_acc), np.asarray(toks)


def test_spec_accept_greedy_matches_argmax_chain():
    """Greedy rows accept exactly the drafts matching the argmax chain
    and emit the argmax at the first mismatch (or the bonus argmax)."""
    rng = np.random.RandomState(0)
    logits = rng.randn(1, 4, 16).astype(np.float32)
    chain = logits[0].argmax(-1)  # (4,)
    # perfect drafts: all accepted + bonus
    n, t = _accept(logits, chain[None, :3], 3, temp=0.0)
    assert n[0] == 3 and list(t[0, :4]) == list(chain)
    # mismatch at lane 1: accept 1, emit argmax of lane 1
    drafts = chain[:3].copy()
    drafts[1] = (drafts[1] + 1) % 16
    n, t = _accept(logits, drafts[None], 3, temp=0.0)
    assert n[0] == 1 and list(t[0, :2]) == [chain[0], chain[1]]
    # no drafts: plain decode, emit argmax of lane 0
    n, t = _accept(logits, np.zeros((1, 3), np.int32), 0, temp=0.0)
    assert n[0] == 0 and t[0, 0] == chain[0]


def test_spec_accept_marginal_matches_baseline_sampler():
    """The emitted token at the first burst position must be distributed
    exactly like the baseline sampler's draw from the same logits —
    whatever the draft was. Empirical check over many seeds on a toy
    vocab, draft = a mid-probability token."""
    rng = np.random.RandomState(1)
    v = 8
    logits = np.tile(rng.randn(1, 1, v).astype(np.float32), (1, 3, 1))
    target = np.exp(logits[0, 0]) / np.exp(logits[0, 0]).sum()
    draft = int(np.argsort(-target)[2])  # neither peak nor tail
    counts = np.zeros(v)
    trials = 4000
    for s in range(trials):
        _, t = _accept(logits, np.full((1, 2), draft, np.int32), 2,
                       temp=1.0, seed=s)
        counts[t[0, 0]] += 1
    emp = counts / trials
    # generous tolerance: 4000 draws, 8 bins -> ~3 sigma of a p=0.25 bin
    assert np.abs(emp - target).max() < 0.035, (emp, target)
    # and the accept rate of the draft lane is ~q(draft): the draft token
    # appears at position 0 with prob q(d) + residual 0 = q(d)
    assert abs(emp[draft] - target[draft]) < 0.035


def test_spec_accept_respects_filters():
    """Acceptance is judged against the FILTERED target distribution: a
    draft outside the top-k support can never be accepted, and the
    resampled token stays inside the support."""
    logits = np.zeros((1, 3, 8), np.float32)
    logits[0, :, :3] = [3.0, 2.5, 2.0]  # top_k=2 support: {0, 1}
    for s in range(50):
        n, t = _accept(logits, np.full((1, 2), 5, np.int32), 2,
                       temp=1.0, top_k=2, seed=s)
        assert n[0] == 0
        assert t[0, 0] in (0, 1)


def test_spec_accept_lanes_bitwise_match_baseline_sampler():
    """Exact-match acceptance: lane j's chain token must be BIT-identical
    to what `sample_tokens` would draw from the same logits at step+j —
    same key, same filtered distribution — for greedy and sampled rows
    alike. This is the property that makes speculative serving
    token-for-token the baseline engine at any temperature."""
    rng = np.random.RandomState(3)
    logits = rng.randn(3, 4, 16).astype(np.float32)
    temp = np.array([0.0, 1.0, 0.7], np.float32)
    top_k = np.array([0, 5, 0], np.int32)
    top_p = np.array([1.0, 1.0, 0.9], np.float32)
    seed = np.array([4, 5, 6], np.int32)
    step0 = np.array([0, 3, 10], np.int32)
    _, chain = spec_accept_tokens(
        jnp.asarray(logits), np.zeros((3, 3), np.int32),
        np.full((3,), 3, np.int32), temp, top_k, top_p, seed, step0,
    )
    chain = np.asarray(chain)
    for j in range(4):
        want = np.asarray(sample_tokens(
            jnp.asarray(logits[:, j]), temp, top_k, top_p, seed, step0 + j,
        ))
        np.testing.assert_array_equal(chain[:, j], want, err_msg=f"lane {j}")


def test_spec_accept_deterministic_in_seed_and_step():
    rng = np.random.RandomState(2)
    logits = rng.randn(2, 4, 16).astype(np.float32)
    drafts = rng.randint(0, 16, size=(2, 3))
    a = _accept(logits, drafts, 3, temp=1.0, seed=9, step=4)
    b = _accept(logits, drafts, 3, temp=1.0, seed=9, step=4)
    c = _accept(logits, drafts, 3, temp=1.0, seed=9, step=5)
    np.testing.assert_array_equal(a[1], b[1])
    assert not np.array_equal(a[1], c[1]) or not np.array_equal(a[0], c[0])


# ---------------------------------------------------------------------------
# Non-finite / fully-masked robustness (the sampler guard the engine's
# poisoned-row retirement builds on)
# ---------------------------------------------------------------------------


def test_nonfinite_logits_never_produce_invalid_tokens():
    """NaN/+inf rows must still sample IN-RANGE tokens (non-finite
    entries coerce to -inf; the other rows are untouched)."""
    rng = np.random.RandomState(7)
    logits = rng.randn(4, 16).astype(np.float32)
    logits[1, :] = np.nan
    logits[2, 5] = np.inf
    logits[3, 0] = -np.inf
    toks = _call(logits, [SamplingParams(temperature=t, seed=i)
                          for i, t in enumerate([0.0, 1.0, 0.0, 1.0])])
    assert ((toks >= 0) & (toks < 16)).all()
    # clean rows sample exactly as if the poisoned rows weren't there
    clean = _call(logits[:1], [SamplingParams(temperature=0.0)])
    assert toks[0] == clean[0] == logits[0].argmax()
    # +inf wins greedy once coerced? No: +inf -> -inf, finite max wins.
    finite = np.where(np.isfinite(logits[2]), logits[2], -np.inf)
    assert toks[2] == finite.argmax()


def test_fully_masked_row_is_defined():
    """A row with NO support (all -inf after filtering) must not
    propagate NaN — guard_support falls back to uniform logits, and the
    categorical stays defined for every row of the batch."""
    from repro.serve.sampling import guard_support

    logits = np.full((2, 8), -np.inf, np.float32)
    logits[0] = np.arange(8)
    guarded, support = guard_support(jnp.asarray(logits))
    support = np.asarray(support)
    assert support.tolist() == [True, False]
    assert np.isfinite(np.asarray(guarded)).all()
    toks = _call(logits, [SamplingParams(temperature=1.0, seed=3),
                          SamplingParams(temperature=1.0, seed=4)])
    assert ((toks >= 0) & (toks < 8)).all()


def test_finite_rows_flags_exactly_the_poisoned_rows():
    from repro.serve.sampling import finite_rows, sample_tokens_checked

    rng = np.random.RandomState(8)
    logits = rng.randn(4, 16).astype(np.float32)
    logits[2, 3] = np.nan
    ok = np.asarray(finite_rows(jnp.asarray(logits)))
    assert ok.tolist() == [True, True, False, True]
    sp = stack_params([SamplingParams(temperature=0.0)] * 4)
    toks, ok2 = sample_tokens_checked(
        jnp.asarray(logits), sp["temperature"], sp["top_k"], sp["top_p"],
        sp["seed"], np.zeros((4,), np.int32),
    )
    np.testing.assert_array_equal(np.asarray(ok2), ok)
    # the fused program's tokens are the plain sampler's tokens
    np.testing.assert_array_equal(
        np.asarray(toks),
        _call(logits, [SamplingParams(temperature=0.0)] * 4),
    )
