"""Batched per-request sampler suite (serve/sampling.py)."""
import jax.numpy as jnp
import numpy as np

from repro.serve.sampling import (
    SamplingParams,
    sample_tokens,
    stack_params,
)


def _call(logits, params_list, step=0):
    sp = stack_params(params_list)
    steps = np.full((len(params_list),), step, np.int32)
    return np.asarray(sample_tokens(
        jnp.asarray(logits, jnp.float32), sp["temperature"], sp["top_k"],
        sp["top_p"], sp["seed"], steps,
    ))


def test_temperature_zero_degenerates_to_greedy():
    rng = np.random.RandomState(0)
    logits = rng.randn(4, 32).astype(np.float32)
    toks = _call(logits, [SamplingParams(temperature=0.0, seed=i)
                          for i in range(4)])
    np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_top_k_respected_per_request_in_mixed_batch():
    """Row 0: top-1 at high temperature (must pick the argmax); row 1:
    top-k disabled; row 2: greedy. One batched call, three behaviours."""
    rng = np.random.RandomState(1)
    logits = rng.randn(3, 64).astype(np.float32)
    params = [
        SamplingParams(temperature=5.0, top_k=1, seed=11),
        SamplingParams(temperature=1.0, seed=12),
        SamplingParams(temperature=0.0),
    ]
    for step in range(20):
        toks = _call(logits, params, step=step)
        assert toks[0] == logits[0].argmax()  # top-1 == argmax despite temp
        assert toks[2] == logits[2].argmax()
        assert 0 <= toks[1] < 64


def test_top_k_limits_support():
    """With top_k=k, only the k largest logits can ever be sampled."""
    rng = np.random.RandomState(2)
    logits = rng.randn(2, 32).astype(np.float32)
    k = 5
    allowed = [set(np.argsort(-logits[b])[:k]) for b in range(2)]
    params = [SamplingParams(temperature=3.0, top_k=k, seed=b)
              for b in range(2)]
    for step in range(50):
        toks = _call(logits, params, step=step)
        for b in range(2):
            assert toks[b] in allowed[b], (b, toks[b])


def test_top_p_limits_support():
    """A spiked distribution with top_p=0.5 must only ever sample the
    spike (its prob ~1 exceeds the nucleus alone)."""
    logits = np.zeros((2, 16), np.float32)
    logits[:, 3] = 10.0  # p(3) ~ 0.9998
    params = [SamplingParams(temperature=1.0, top_p=0.5, seed=b)
              for b in range(2)]
    for step in range(20):
        toks = _call(logits, params, step=step)
        assert (toks == 3).all()


def test_top_p_one_keeps_full_support():
    """top_p=1.0 must not mask anything: over many draws from a uniform
    distribution, more than one token appears."""
    logits = np.zeros((1, 8), np.float32)
    params = [SamplingParams(temperature=1.0, top_p=1.0, seed=0)]
    seen = {int(_call(logits, params, step=s)[0]) for s in range(40)}
    assert len(seen) > 1


def test_seeds_reproducible_and_batch_independent():
    """Row i's stream depends only on (seed_i, step) — not on batch
    position or on what other rows are doing."""
    rng = np.random.RandomState(3)
    row = rng.randn(1, 32).astype(np.float32)
    p = SamplingParams(temperature=1.0, seed=42)

    solo = [int(_call(row, [p], step=s)[0]) for s in range(8)]
    # same request in slot 2 of a 4-row batch with unrelated neighbours
    batch_logits = np.concatenate(
        [rng.randn(2, 32).astype(np.float32), row,
         rng.randn(1, 32).astype(np.float32)], 0
    )
    others = [SamplingParams(temperature=0.7, top_k=3, seed=7),
              SamplingParams(temperature=0.0),
              p,
              SamplingParams(temperature=1.2, top_p=0.8, seed=9)]
    batched = [int(_call(batch_logits, others, step=s)[2])
               for s in range(8)]
    assert solo == batched
    # and a different seed gives a different stream
    p2 = SamplingParams(temperature=1.0, seed=43)
    other = [int(_call(row, [p2], step=s)[0]) for s in range(8)]
    assert solo != other


def test_top_p_zero_degenerates_to_top1():
    """top_p=0 must still keep the rank-0 token sampleable."""
    rng = np.random.RandomState(4)
    logits = rng.randn(2, 16).astype(np.float32)
    params = [SamplingParams(temperature=2.0, top_p=0.0, seed=b)
              for b in range(2)]
    for step in range(10):
        toks = _call(logits, params, step=step)
        np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_filters_compose():
    """top_k and top_p both active: support is the intersection."""
    logits = np.zeros((1, 16), np.float32)
    logits[0, :4] = np.array([5.0, 4.9, 4.8, 4.7])
    # top_k=2 keeps {0,1}; top_p tiny keeps {0}; intersection {0}
    params = [SamplingParams(temperature=1.0, top_k=2, top_p=0.05, seed=0)]
    for step in range(20):
        assert int(_call(logits, params, step=step)[0]) == 0
