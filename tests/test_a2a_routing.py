"""All-to-all expert routing (the §Perf C5 mechanism) vs single-device
reference, on 8 fake devices in a subprocess."""
import os
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.a2a_routing import make_a2a_moe

E, K, D, FF, T = 16, 2, 32, 64, 128
mesh = jax.make_mesh((8,), ("model",))
rng = jax.random.PRNGKey(0)
ks = jax.random.split(rng, 5)
x = jax.random.normal(ks[0], (T, D))
router = jax.random.normal(ks[1], (D, E)) * 0.1
wg = jax.random.normal(ks[2], (E, D, FF)) * 0.05
wu = jax.random.normal(ks[3], (E, D, FF)) * 0.05
wd = jax.random.normal(ks[4], (E, FF, D)) * 0.05

# single-device reference: dense dropless top-k
logits = x @ router
probs = jax.nn.softmax(logits, -1)
gate, idx = jax.lax.top_k(probs, K)
gate_n = gate / gate.sum(-1, keepdims=True)
ref = jnp.zeros_like(x)
for kk in range(K):
    e = idx[:, kk]
    g = jax.nn.silu(jnp.einsum("td,tdf->tf", x, wg[e]))
    u = jnp.einsum("td,tdf->tf", x, wu[e])
    y = jnp.einsum("tf,tfd->td", g * u, wd[e])
    ref = ref + gate_n[:, kk, None] * y

moe = make_a2a_moe(mesh, num_experts=E, top_k=K, d_model=D,
                   capacity_factor=8.0)  # slack: no drops
xs = jax.device_put(x, NamedSharding(mesh, P("model", None)))
got = jax.jit(moe)(xs, router, wg, wu, wd)
err = float(jnp.abs(got - ref).max()) / (float(jnp.abs(ref).max()) + 1e-9)
assert err < 1e-3, err
# and the exchanged payload is bounded: the compiled HLO uses all-to-all
hlo = jax.jit(moe).lower(xs, router, wg, wu, wd).compile().as_text()
assert "all-to-all" in hlo
print("OK", err)
"""


def test_a2a_routing_matches_reference():
    r = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=".",
    )
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])
