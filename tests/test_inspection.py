"""Model inspection (paper §5): routing statistics sanity."""
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import moe_init
from repro.core.inspection import routing_stats, summarize


def test_routing_stats():
    cfg = MoEConfig(variant="soft", num_experts=16, expert_d_ff=32)
    params = moe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32))
    stats = routing_stats(x, params)
    # total dispatch mass equals total slots (each slot's column sums to 1)
    total = float(stats["token_contribution"].sum(-1).mean())
    assert abs(total - 16) < 1e-3
    # no token at zero contribution (paper: no dropping)
    assert float(stats["token_contribution_min"]) > 0
    # covering 90% of a slot needs at least as many tokens as 50%
    assert bool(
        (stats["tokens_for_90pct"] >= stats["tokens_for_50pct"]).all()
    )
    s = summarize(stats)
    assert "expert_importance_spread" in s
    assert s["max_dispatch_weight"] <= 1.0


def test_chunked_routing_stats_match_dense_oracle():
    cfg = MoEConfig(variant="soft", num_experts=16, expert_d_ff=32)
    params = moe_init(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32))
    dense = routing_stats(x, params)
    for chunk in (7, 16, 48, 512):  # ragged, even, whole, oversize
        chunked = routing_stats(x, params, method="chunked",
                                chunk_tokens=chunk)
        for k, v in chunked.items():
            assert k in dense
            assert jnp.allclose(jnp.asarray(v), jnp.asarray(dense[k]),
                                atol=1e-4, rtol=1e-4), (k, chunk)
    # the sort-based cumulative curves are dense-only
    assert "tokens_for_50pct" not in routing_stats(x, params,
                                                   method="chunked")
