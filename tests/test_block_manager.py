"""BlockManager host-side invariants: alloc/free/refcount round trips,
fork sharing, COW bookkeeping — plus device-level block clear/copy."""
import jax.numpy as jnp
import pytest

from repro.serve.block_manager import BlockManager


def test_alloc_free_roundtrip():
    m = BlockManager(num_blocks=5)  # block 0 reserved null
    assert m.num_free == 4
    a = m.alloc(2)
    assert len(a) == 2 and 0 not in a
    assert m.num_free == 2 and m.num_used == 2
    assert all(m.ref[b] == 1 for b in a)
    for b in a:
        assert m.decref(b)  # freed at zero
    assert m.num_free == 4 and m.num_used == 0
    # freed blocks come back (LIFO)
    b = m.alloc(4)
    assert sorted(b) == [1, 2, 3, 4]


def test_alloc_overflow_guarded():
    m = BlockManager(num_blocks=3)
    m.alloc(2)
    assert not m.can_alloc(1)
    with pytest.raises(AssertionError):
        m.alloc(1)


def test_refcount_sharing():
    m = BlockManager(num_blocks=4)
    (b,) = m.alloc(1)
    m.incref(b)  # second owner (e.g. radix node)
    assert m.needs_cow(b)
    assert not m.decref(b)  # still one owner
    assert not m.needs_cow(b)
    assert m.decref(b)  # now freed
    assert m.num_free == 3


def test_double_free_rejected():
    m = BlockManager(num_blocks=3)
    (b,) = m.alloc(1)
    m.decref(b)
    with pytest.raises(AssertionError):
        m.decref(b)


def test_null_block_pinned():
    m = BlockManager(num_blocks=3)
    with pytest.raises(AssertionError):
        m.incref(0)
    with pytest.raises(AssertionError):
        m.decref(0)
    # null never appears in allocations however hard we churn
    for _ in range(3):
        blocks = m.alloc(2)
        assert 0 not in blocks
        for b in blocks:
            m.decref(b)


def test_fork_table_cow_lifecycle():
    """Fork shares every real block; a write to a shared block must COW
    (needs_cow True), and after the copy both tables free independently."""
    m = BlockManager(num_blocks=8)
    table = m.alloc(3) + [0, 0]  # 3 real blocks, 2 null entries
    clone = m.fork_table(table)
    assert clone == table
    assert all(m.needs_cow(b) for b in table if b != 0)
    # COW on the clone's block 1: new private block, old loses one ref
    old = clone[1]
    (new,) = m.alloc(1)
    m.decref(old)
    clone[1] = new
    assert not m.needs_cow(table[1])  # parent now sole owner again
    # retire both tables: every block drains to the free list
    for b in table + clone:
        if b != 0:
            m.decref(b)
    assert m.num_used == 0


def test_high_water_tracks_peak_not_current():
    m = BlockManager(num_blocks=10)
    a = m.alloc(5)
    for b in a[:4]:
        m.decref(b)
    m.alloc(1)
    assert m.num_used == 2
    assert m.high_water == 5


def test_device_clear_and_copy_blocks():
    """The jitted block clear/copy programs: clear invalidates only the
    targeted blocks' pos; copy moves KV content block-for-block (the COW
    device op); padded out-of-range ids are dropped."""
    from repro.configs import get_config, reduced
    from repro.serve.block_manager import init_paged_cache
    from repro.serve.programs import clear_blocks_program, copy_blocks_program

    cfg = reduced(get_config("llama3-8b"))
    cache = init_paged_cache(cfg, num_blocks=4, block_size=4, num_slots=2)
    # paint every pos valid, every k distinct per block
    painted = []
    for layer in cache:
        a = dict(layer["attn"])
        a["pos"] = jnp.tile(jnp.arange(4)[:, None], (1, 4)) * 10
        a["k"] = jnp.ones_like(a["k"]) * jnp.arange(4).reshape(4, 1, 1, 1)
        painted.append({"attn": a})
    cache = painted

    cleared = clear_blocks_program(cache, jnp.asarray([2, 99, 99, 99]))
    for layer in cleared:
        pos = layer["attn"]["pos"]
        assert (pos[2] == -1).all()  # cleared
        assert (pos[1] == 10).all() and (pos[3] == 30).all()  # untouched

    copied = copy_blocks_program(cache, jnp.asarray([3, 0, 0, 0]),
                                 jnp.asarray([1, 99, 99, 99]))
    for layer in copied:
        assert (layer["attn"]["k"][1] == 3).all()  # 3 -> 1 copied
        assert (layer["attn"]["pos"][1] == 30).all()
        assert (layer["attn"]["k"][3] == 3).all()  # source intact
