"""Roofline machinery: HLO collective parsing (trip counts, replica groups,
pod-crossing), CPU-upcast correction, analytic-vs-HLO FLOPs cross-check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.distributed.compat import cost_analysis_dict
from repro.roofline.analysis import model_flops
from repro.roofline.flops import analytic_cost, fwd_flops
from repro.roofline.hlo_parse import (
    Collective,
    cpu_upcast_correction,
    parse_module_collectives,
)

FAKE_HLO = """
HloModule test, is_scheduled=true

%inner_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ar0 = f32[8,8]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], channel_id=1
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar0)
}

%outer_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %w2 = (s32[], f32[8,8]) while(%t0), condition=%c, body=%inner_body, backend_config={"known_trip_count":{"n":"3"}}
  %ag = f32[16,4]{1,0} all-gather(%y), replica_groups=[4,2]<=[2,4]T(1,0), channel_id=2
  ROOT %t2 = (s32[], f32[8,8]) tuple(%i2, %w2)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %w1 = (s32[], f32[8,8]) while(%t1), condition=%c2, body=%outer_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %done = f32[8,8] copy(%a)
}
"""


def test_parse_nested_trip_counts():
    mc = parse_module_collectives(FAKE_HLO)
    counts = mc.counts()
    # inner all-reduce: 5 (outer) x 3 (inner) = 15; all-gather: 5
    assert counts["all-reduce"] == 15
    assert counts["all-gather"] == 5
    by = mc.by_kind()
    assert by["all-reduce"] == 15 * 8 * 8 * 4
    assert by["all-gather"] == 5 * 16 * 4 * 4


def test_pod_crossing_detection():
    # groups [4,2]<=[2,4]T(1,0): transpose makes groups {0,4},{1,5},... —
    # with pod_size=4 those cross pods.
    mc = parse_module_collectives(FAKE_HLO, pod_size=4)
    ag = [c for c in mc.collectives if c.kind == "all-gather"][0]
    assert ag.crosses_pod
    ar = [c for c in mc.collectives if c.kind == "all-reduce"][0]
    assert not ar.crosses_pod  # [2,4]<=[8]: contiguous groups of 4


def test_alg_factors():
    c = Collective("all-reduce", 100, 4, False)
    assert c.alg_factor() == pytest.approx(2 * 3 / 4)
    c = Collective("all-gather", 100, 4, False)
    assert c.alg_factor() == pytest.approx(3 / 4)
    c = Collective("collective-permute", 100, 4, False)
    assert c.alg_factor() == 1.0


def test_cpu_upcast_correction_detects_converts():
    txt = """
ENTRY %m (p: bf16[1000,1000]) -> f32[1000,1000] {
  %p0 = bf16[1000,1000]{1,0} parameter(0)
  %big = f32[10000,10000]{1,0} convert(%w)
  %w = bf16[10000,10000]{1,0} parameter(1)
  ROOT %r = f32[1000,1000] convert(%p0)
}
"""
    # 10000x10000 f32 = 400MB > threshold; 1000x1000 f32 = 4MB < threshold
    assert cpu_upcast_correction(txt) == 10000 * 10000 * 4


def test_analytic_flops_cross_check_vs_hlo():
    """On an UNROLLED graph (decode path, no scan) XLA's cost_analysis is
    trustworthy — the analytic model must agree within 2x (it ignores
    elementwise ops; XLA ignores some fusions)."""
    from repro.configs import reduced
    from repro.models import init_cache, lm_apply

    cfg = reduced(get_config("llama3-8b"))
    from repro.models import lm_init

    params = lm_init(jax.random.PRNGKey(0), cfg)
    B, S = 4, 64
    cache = init_cache(cfg, B, S)
    toks = jnp.zeros((B, 1), jnp.int32)

    def decode(p, t, cache):
        return lm_apply(p, cfg, t, positions=jnp.arange(63, 64),
                        cache=cache, mode="decode")[0]

    c = jax.jit(decode).lower(params, toks, cache).compile()
    # cost_analysis() returns a list-of-dicts on jax 0.4.x — normalize
    hlo_flops = cost_analysis_dict(c).get("flops", 0)
    ana = fwd_flops(cfg, B, 1, "decode", cache_len=S)
    assert ana > 0 and hlo_flops > 0
    ratio = ana / hlo_flops
    assert 0.4 < ratio < 2.5, f"analytic/HLO flops ratio {ratio:.2f}"


def test_model_flops_moe_uses_active_params():
    cfg_moe = get_config("deepseek-v2-lite-16b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg_moe, shape, "train")
    dense_equiv = 6.0 * cfg_moe.param_count() * shape.global_batch * shape.seq_len
    assert mf < dense_equiv * 0.5  # top-6/64 of experts active


def test_analytic_cost_modes():
    cfg = get_config("llama3-8b")
    tr = analytic_cost(cfg, "train_4k")
    pf = analytic_cost(cfg, "prefill_32k")
    dc = analytic_cost(cfg, "decode_32k")
    assert tr.flops_global > pf.flops_global > dc.flops_global
    # decode is dominated by bytes (params + cache), train by flops
    assert dc.bytes_global > dc.flops_global / 1000
