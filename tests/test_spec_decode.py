"""Speculative-decoding correctness: greedy token-for-token parity with
the non-speculative engines across arch families, statistically unchanged
sampled distributions, EOS-inside-burst truncation, exact block/refcount
rollback, drafter behaviour, finish reasons, and zero recompiles."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    NgramDrafter,
    Request,
    SamplingParams,
    ServeEngine,
    SpecConfig,
)

_PARAMS = {}


def _setup(name):
    if name not in _PARAMS:
        cfg = reduced(get_config(name))
        _PARAMS[name] = (cfg, lm_init(jax.random.PRNGKey(0), cfg))
    return _PARAMS[name]


_PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [4] * 9]


def _run(name, spec, *, max_new=12, max_len=64, eos=None, kw=None,
         sampling=None, batch=2):
    cfg, params = _setup(name)
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      eos_id=eos, spec=spec, **(kw or {}))
    reqs = [
        Request(prompt=list(p), max_new_tokens=max_new,
                sampling=sampling or SamplingParams())
        for p in _PROMPTS
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return eng, reqs


_PAGED = {"kw": {"backend": "paged", "block_size": 8}}


# ---------------------------------------------------------------------------
# greedy parity — the acceptance criterion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-27b", "qwen2-0.5b",
                                  "granite-moe-1b-a400m",
                                  "deepseek-v2-lite-16b"])
def test_greedy_spec_matches_baseline_paged(arch):
    """Greedy speculation must be token-for-token the plain paged engine's
    stream on dense, GQA-bias, sliding-window AND sparse-MoE arch
    families — whatever the drafter proposes, acceptance keeps exactly
    the argmax chain. The MoE rows became exact when serving routing went
    per-row/dropless: the (B, k+1) verify forward now equals k+1 single
    decode steps on sparse-MoE archs (previously ≈, a lifted
    restriction)."""
    _, base = _run(arch, None, **_PAGED)
    eng, spec = _run(arch, SpecConfig(k=4), **_PAGED)
    assert [r.out for r in spec] == [r.out for r in base]
    assert eng.spec_stats()["verify_calls"] > 0


def test_greedy_spec_matches_baseline_contiguous():
    """Full-length rings (no sliding window) support speculation on the
    contiguous backend too."""
    _, base = _run("llama3-8b", None)
    _, spec = _run("llama3-8b", SpecConfig(k=3))
    assert [r.out for r in spec] == [r.out for r in base]


def test_spec_counts_fewer_model_calls():
    """On a repetitive greedy stream the n-gram drafter must actually
    accelerate: strictly fewer decode model calls than the plain engine
    on the SAME workload (same batch — batching amortization cancels
    out), with a nonzero acceptance rate."""
    plain_eng, _ = _run("llama3-8b", None, max_new=24, **_PAGED)
    eng, reqs = _run("llama3-8b", SpecConfig(k=4), max_new=24, **_PAGED)
    stats = eng.spec_stats()
    assert stats["accepted"] > 0, "no draft token was ever accepted"
    assert eng.decode_steps < plain_eng.decode_steps


# ---------------------------------------------------------------------------
# unsupported configurations are rejected loudly
# ---------------------------------------------------------------------------


def test_spec_rejects_ssm_archs():
    cfg, params = _setup("mamba2-370m")
    with pytest.raises(ValueError, match="SSM"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    backend="paged", spec=SpecConfig(k=4))


def test_spec_rejects_wrapping_contiguous_ring():
    """gemma3's reduced sliding window (16) < max_len: a rejected write
    would evict live ring entries — contiguous speculation must refuse
    and point at the paged backend (which stores every position)."""
    cfg, params = _setup("gemma3-27b")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    spec=SpecConfig(k=4))


# ---------------------------------------------------------------------------
# EOS inside an accepted burst
# ---------------------------------------------------------------------------


def test_eos_inside_burst_truncates():
    """When EOS rides in mid-burst (accepted draft), tokens after it must
    be discarded — never appended, never streamed — and the stream must
    equal the non-speculative engine's with the same eos_id."""
    _, probe = _run("llama3-8b", None, max_new=12, **_PAGED)
    eos = probe[0].out[2]  # fires mid-stream, inside the first bursts
    _, base = _run("llama3-8b", None, max_new=12, eos=eos, **_PAGED)

    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64, eos_id=eos,
                      backend="paged", block_size=8, spec=SpecConfig(k=4))
    streamed = {}
    reqs = []
    for p in _PROMPTS:
        r = Request(prompt=list(p), max_new_tokens=12)
        streamed[id(r)] = []
        r.on_token = lambda req, tok: streamed[id(req)].append(tok)
        reqs.append(r)
        eng.submit(r)
    eng.run()
    assert [r.out for r in reqs] == [r.out for r in base]
    for r in reqs:
        assert streamed[id(r)] == r.out, "streamed past the truncation"
        if r.finish_reason == "eos":
            assert r.out[-1] == eos and eos not in r.out[:-1]


def test_finish_reasons_all_paths():
    """eos / length / cache_ceiling are distinguished, speculative or
    not."""
    cfg, params = _setup("llama3-8b")
    for spec in (None, SpecConfig(k=4)):
        # length: budget exhausted
        eng, reqs = _run("llama3-8b", spec, max_new=4, **_PAGED)
        assert all(r.finish_reason == "length" for r in reqs)
        # cache_ceiling: prompt+generation hits max_len before the budget.
        # engine.submit validates prompt+max_new <= max_len (so well-formed
        # traffic can never hit the ceiling); inject via the scheduler to
        # exercise the defensive path.
        eng = ServeEngine(cfg, params, batch_size=1, max_len=16,
                          backend="paged", block_size=8, spec=spec)
        r = Request(prompt=list(range(1, 11)), max_new_tokens=32)
        eng.sched.submit(r)
        eng.run()
        assert r.done and r.finish_reason == "cache_ceiling"
        assert len(r.prompt) + len(r.out) == 17  # emitted at the ceiling
        # eos
        _, probe = _run("llama3-8b", None, max_new=8, **_PAGED)
        eng, reqs = _run("llama3-8b", spec, max_new=8,
                         eos=probe[0].out[1], **_PAGED)
        assert any(r.finish_reason == "eos" for r in reqs)


# ---------------------------------------------------------------------------
# rollback leaves block/refcount state identical to never-having-drafted
# ---------------------------------------------------------------------------


class _GarbageDrafter:
    """Proposes tokens the greedy chain will (almost surely) reject."""

    def __init__(self, vocab):
        self.vocab = vocab

    def propose(self, context, k):
        return [(context[-1] + 7919 + i) % self.vocab for i in range(k)]


def test_rollback_restores_block_manager_state():
    """Every speculative tick with a drafter designed to be rejected must
    leave the BlockManager in the never-having-drafted state: the row's
    blocks cover exactly positions [0, e.pos] (the footprint
    `ensure_decode_block(e.pos)` leaves on the non-speculative path —
    e.pos is the pending token's write position), every block refcount
    is 1, nothing leaks from the free list, and every pool `pos` entry
    beyond the committed frontier is scrubbed back to -1."""
    cfg, params = _setup("llama3-8b")
    bs = 4
    spec_cfg = SpecConfig(k=4, drafter=_GarbageDrafter(cfg.vocab_size))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", block_size=bs,
                      prefix_cache=False, spec=spec_cfg)
    req = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=10)
    eng.submit(req)
    be = eng.backend
    checked = 0
    while not req.done:
        eng.step()
        live = list(eng.sched.live.values())
        if not live or live[0].state != "decode":
            continue
        (e,) = live
        row = be.tables[e.slot]
        n_blocks = int((row != 0).sum())
        want = e.pos // bs + 1  # blocks covering positions 0..e.pos
        assert n_blocks == want, (n_blocks, want, e.pos)
        assert (row[want:] == 0).all(), "burst block beyond e.pos leaked"
        for b in row[:want]:
            assert be.mgr.ref[int(b)] == 1
        assert be.mgr.num_used == n_blocks
        # committed frontier = e.pos - 1 (the pending token at e.pos is
        # recorded but not yet written); beyond it every pool entry the
        # row's blocks hold must be scrubbed to -1
        frontier = e.pos - 1
        pos0 = np.asarray(eng.backend.cache[0]["attn"]["pos"])
        for lb, b in enumerate(row[:want]):
            blk = pos0[int(b)]
            for off in range(bs):
                logical = lb * bs + off
                if logical <= frontier:
                    assert blk[off] == logical, (lb, off, blk[off])
                else:
                    assert blk[off] == -1, (
                        f"stale speculative write at {logical}: {blk[off]}"
                    )
        checked += 1
    assert checked >= 5, "loop never inspected a live decode row"
    assert eng.spec_stats()["drafted"] > 0
    assert eng.spec_stats()["accepted"] == 0  # garbage got rejected
    # drained: everything returns to the free list
    assert be.mgr.num_used == 0

    # and the stream itself equals the plain engine's
    plain = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        backend="paged", block_size=bs, prefix_cache=False)
    ref = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=10)
    plain.submit(ref)
    plain.run()
    assert req.out == ref.out


def test_moe_rollback_exact_pool_state():
    """Sparse-MoE + all-rejected drafts: rollback must leave the paged
    pool equal to never having drafted — per-row dropless routing means
    no MoE-side state exists that a rejected lane could have advanced,
    so the pos-scrub + rollback_burst contract carries over verbatim.
    Stream parity with the plain engine is asserted on top."""
    cfg, params = _setup("granite-moe-1b-a400m")
    spec_cfg = SpecConfig(k=4, drafter=_GarbageDrafter(cfg.vocab_size),
                          disable_after_rejects=0)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", block_size=4, prefix_cache=False,
                      spec=spec_cfg)
    req = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=10)
    eng.submit(req)
    eng.run()
    assert eng.spec_stats()["drafted"] > 0
    assert eng.spec_stats()["accepted"] == 0  # garbage got rejected
    assert eng.backend.mgr.num_used == 0  # drained: nothing leaked

    plain = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        backend="paged", block_size=4, prefix_cache=False)
    ref = Request(prompt=[5, 4, 3, 2, 1], max_new_tokens=10)
    plain.submit(ref)
    plain.run()
    assert req.out == ref.out
    # pool `pos` arrays equal to the never-drafted engine's for every
    # layer: the stale speculative writes are scrubbed, not just masked
    for spec_c, plain_c in zip(eng.backend.cache, plain.backend.cache):
        sp = np.asarray(spec_c["attn"]["pos"])
        pl = np.asarray(plain_c["attn"]["pos"])
        assert (np.sort(sp[sp >= 0]) == np.sort(pl[pl >= 0])).all()


def test_rollback_all_blocks_freed_at_drain():
    """After a speculative run drains, the pool must be fully free — no
    block leaked by burst reservations."""
    eng, _ = _run("llama3-8b", SpecConfig(k=4), max_new=20,
                  kw={"backend": "paged", "block_size": 4,
                      "prefix_cache": False})
    assert eng.backend.mgr.num_used == 0
    assert eng.backend.num_free_slots == eng.batch


def test_preemption_under_pressure_with_spec():
    """Burst reservations must degrade (shrink/preempt), not corrupt: a
    pool too small for two rows still finishes both with the exact
    unconstrained greedy streams."""
    cfg, params = _setup("llama3-8b")

    def mk():
        return [Request(prompt=[3, 1, 4, 1, 5, 9, 2, 6],
                        max_new_tokens=12) for _ in range(2)]

    ref = mk()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    for r in ref:
        eng.submit(r)
    eng.run()
    tight = ServeEngine(cfg, params, batch_size=2, max_len=32,
                        backend="paged", block_size=4, num_blocks=7,
                        prefix_cache=False, spec=SpecConfig(k=4))
    reqs = mk()
    for r in reqs:
        tight.submit(r)
    tight.run()
    assert [r.out for r in reqs] == [r.out for r in ref]


# ---------------------------------------------------------------------------
# sampled (temperature > 0) speculation
# ---------------------------------------------------------------------------


def test_sampled_spec_matches_baseline_token_for_token():
    """Exact-match acceptance draws each lane with the baseline sampler's
    own key and filtered logits, so SAMPLED speculation (temperature,
    top-k, top-p all active) must reproduce the non-speculative engine's
    stream token-for-token — not merely in distribution (the marginal
    math is additionally tested in tests/test_sampling.py)."""
    sp = SamplingParams(temperature=1.0, top_k=20, top_p=0.9, seed=11)
    _, base = _run("llama3-8b", None, sampling=sp, **_PAGED)
    eng, a = _run("llama3-8b", SpecConfig(k=4), sampling=sp, **_PAGED)
    assert [r.out for r in a] == [r.out for r in base]
    assert eng.spec_stats()["drafted"] > 0
    # and reproducible run-to-run
    _, b = _run("llama3-8b", SpecConfig(k=4), sampling=sp, **_PAGED)
    assert [r.out for r in a] == [r.out for r in b]


# ---------------------------------------------------------------------------
# zero recompiles under churn
# ---------------------------------------------------------------------------


def test_spec_zero_recompiles_under_churn():
    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend="paged", block_size=8, spec=SpecConfig(k=4))
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=6))
    eng.run()
    sizes = eng.jit_cache_sizes()
    reqs = [
        Request(prompt=[1, 2, 3] + list(range(i + 4)), max_new_tokens=2 + i)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.jit_cache_sizes() == sizes, (
        f"spec programs recompiled: {sizes} -> {eng.jit_cache_sizes()}"
    )


# ---------------------------------------------------------------------------
# drafter
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    d = NgramDrafter(max_n=3, min_n=1)
    # trailing [1,2,3] matched earlier; proposes the continuation
    assert d.propose([1, 2, 3, 9, 9, 1, 2, 3], 3) == [9, 9, 1]
    # recency: the MOST RECENT earlier occurrence wins
    assert d.propose([1, 2, 5, 1, 2, 7, 1, 2], 1) == [7]
    # falls back to shorter n-grams; the most recent [4] is at index 1
    assert d.propose([4, 4, 9, 7, 4], 2) == [9, 7]
    # nothing to match
    assert d.propose([1, 2, 3], 4) == []
    assert d.propose([], 4) == []
    assert d.propose([1, 2, 3, 1], 0) == []


# ---------------------------------------------------------------------------
# generated-token prefix caching (ROADMAP follow-up)
# ---------------------------------------------------------------------------


def test_cache_generated_hits_past_prompt_boundary():
    """With cache_generated on, a follow-up request whose prompt extends a
    completed request's prompt+output must get prefix hits PAST the
    original prompt boundary — and still produce the cold stream."""
    cfg, params = _setup("llama3-8b")
    prompt = list(range(100, 116))  # 16 tokens = 2 full 8-token blocks
    eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                      backend="paged", block_size=8, prefill_chunk=8,
                      cache_generated=True)
    first = Request(prompt=list(prompt), max_new_tokens=10)
    eng.submit(first)
    eng.run()
    # multi-turn continuation: prompt2 = prompt + output + new user turn
    followup = prompt + first.out + [7, 8]
    eng.submit(Request(prompt=list(followup), max_new_tokens=2))
    eng._admit()
    (entry,) = eng.sched.live.values()
    # matched blocks cover more than the original prompt: hits past the
    # boundary (16 prompt tokens + at least one full generated block)
    assert entry.start_pos > len(prompt)
    eng.run()

    # correctness: same follow-up on a cold engine matches
    cold = ServeEngine(cfg, params, batch_size=1, max_len=64,
                       backend="paged", block_size=8)
    warm_out = None
    for e2 in (eng, cold):
        r = Request(prompt=list(followup), max_new_tokens=6)
        e2.submit(r)
        e2.run()
        if warm_out is None:
            warm_out = r.out
        else:
            assert r.out == warm_out


def test_cache_generated_off_by_default():
    cfg, params = _setup("llama3-8b")
    eng = ServeEngine(cfg, params, batch_size=1, max_len=64,
                      backend="paged", block_size=8, prefill_chunk=8)
    first = Request(prompt=list(range(100, 116)), max_new_tokens=10)
    eng.submit(first)
    eng.run()
    followup = first.prompt + first.out + [7]
    eng.submit(Request(prompt=list(followup), max_new_tokens=2))
    eng._admit()
    (entry,) = eng.sched.live.values()
    assert entry.start_pos <= len(first.prompt)
    eng.run()
