"""End-to-end behaviour: the paper's headline structural claims, verified
at reduced scale on CPU.

  1. Soft-MoE ViT trains and beats fixed-routing ablations (Table 3
     ordering, directionally) on a synthetic task.
  2. Step cost is governed by total slots, not expert count (§2.3).
  3. Serving engine generates deterministically per sequence.
  4. Sharded train step runs on a real (1-device) mesh.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced, soft_moe_vit
from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init
from repro.data import SyntheticImages, SyntheticLM
from repro.models import build_model, lm_init
from repro.optim import OptimizerConfig
from repro.serve import Request, ServeEngine
from repro.train.step import init_train_state, make_train_step


def _train(cfg, steps=60, lr=1e-3, seed=0):
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(seed), init)
    # 32 effective classes keeps the synthetic task learnable in ~100
    # CPU steps (the head stays 1000-wide).
    data = SyntheticImages(
        num_patches=cfg.frontend.num_embeds,
        patch_dim=cfg.frontend.embed_dim, batch_size=16, num_classes=32,
    )
    ocfg = OptimizerConfig(peak_lr=lr, warmup_steps=10, schedule="constant",
                           total_steps=10**9, cooldown_steps=1)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    losses = []
    for s in range(steps):
        state, m = step(state, data.batch(s))
        losses.append(float(m["total_loss"]))
    return losses


def test_soft_moe_vit_learns():
    cfg = reduced(soft_moe_vit("s", 16, 8))
    losses = _train(cfg, steps=100)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first * 0.95, losses[::10]


def test_soft_beats_uniform_ablation():
    """Learned dispatch+combine > fixed uniform mixing (paper Table 3),
    measured as training progress on the same data/seed/steps."""
    base = reduced(soft_moe_vit("s", 16, 8))
    soft_losses = _train(base, steps=100)
    uni = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, variant="uniform")
    )
    uni_losses = _train(uni, steps=100)
    assert np.mean(soft_losses[-10:]) <= np.mean(uni_losses[-10:]) + 0.05


def test_cost_governed_by_slots_not_experts():
    """Fixed total slots, growing experts: the expert compute tensor
    (total slots × d) is identical (paper Fig. 6 — cost ~constant)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 32))
    slot_tensors = []
    for n, p in [(4, 4), (8, 2), (16, 1)]:
        cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=64,
                        slots_per_expert=p)
        params = moe_init(jax.random.PRNGKey(0), 32, cfg)
        y, _ = moe_apply(params, cfg, x)
        slot_tensors.append(n * p)
    assert len(set(slot_tensors)) == 1  # same total slots => same cost


def test_serving_engine_generates():
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=48)
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=6) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    # per-sequence determinism: same prompt -> same continuation
    assert reqs[0].out == reqs[1].out


def test_sharded_train_step_on_host_mesh():
    from repro.distributed import ShardingOptions, use_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.train.step import state_shardings

    cfg = reduced(get_config("llama3-8b"))
    init, loss_fn, _ = build_model(cfg)
    mesh = make_host_mesh()
    with use_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(0), init)
        st_sh = state_shardings(mesh, state, ShardingOptions())
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           batch_size=4)
        ocfg = OptimizerConfig(peak_lr=1e-3, schedule="constant",
                               warmup_steps=0, total_steps=10**9,
                               cooldown_steps=1)
        step = jax.jit(
            make_train_step(loss_fn, ocfg),
            in_shardings=(st_sh, None), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        state, metrics = step(state, data.batch(0))
        assert bool(jnp.isfinite(metrics["total_loss"]))
