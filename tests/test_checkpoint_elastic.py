"""Elastic restore: a checkpoint written from one mesh restores onto a
DIFFERENT mesh/device-count with identical values — the mechanism that
lets a preempted 512-chip job resume on 256 chips (or vice versa)."""
import os
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

d = tempfile.mkdtemp()
mgr = CheckpointManager(d)

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
tree = {
    "w": jax.device_put(
        jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        NamedSharding(mesh_a, P("data", "model")),
    ),
    "b": jax.device_put(
        jnp.arange(16, dtype=jnp.bfloat16),
        NamedSharding(mesh_a, P("model")),
    ),
}
mgr.save(1, tree)

# restore onto a DIFFERENT mesh shape and sharding
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
shardings = {
    "w": NamedSharding(mesh_b, P("model", "data")),
    "b": NamedSharding(mesh_b, P(None)),
}
step, restored = mgr.restore_latest(
    jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           tree),
    shardings=shardings,
)
assert step == 1
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
np.testing.assert_array_equal(
    np.asarray(restored["b"], np.float32), np.asarray(tree["b"], np.float32)
)
assert restored["w"].sharding == shardings["w"]
# and onto a single-axis mesh (elastic shrink)
mesh_c = jax.make_mesh((8,), ("data",))
sh_c = {"w": NamedSharding(mesh_c, P("data", None)),
        "b": NamedSharding(mesh_c, P())}
_, restored_c = mgr.restore_latest(
    jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           tree),
    shardings=sh_c,
)
np.testing.assert_array_equal(np.asarray(restored_c["w"]),
                              np.asarray(tree["w"]))
print("OK")
"""


def test_elastic_restore_across_meshes():
    r = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=".",
    )
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])
