"""Encoder-decoder (seamless): encode/decode paths, cross-attention cache,
decode consistency, Soft-MoE applicability on the encoder side."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced, softify
from repro.models.encdec import (
    encdec_apply,
    encdec_init,
    encdec_loss,
    encode,
    init_encdec_cache,
)


def _setup(soft=False):
    cfg = get_config("seamless-m4t-large-v2")
    if soft:
        cfg = softify(cfg, num_experts=4)
    cfg = reduced(cfg)
    params = encdec_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(
        rng, (B, cfg.frontend.num_embeds, cfg.frontend.embed_dim)
    )
    return cfg, params, toks, frames


def test_train_loss_finite():
    cfg, params, toks, frames = _setup()
    loss, metrics = encdec_loss(params, cfg, {"tokens": toks,
                                              "embeds": frames})
    assert bool(jnp.isfinite(loss))


def test_decode_matches_full_forward():
    cfg, params, toks, frames = _setup()
    B, S = toks.shape
    full, _, _ = encdec_apply(params, cfg, toks, frames)
    enc_out, _ = encode(params, cfg, frames)
    cache = init_encdec_cache(cfg, B, S)
    lp, (eo, cache), _ = encdec_apply(
        params, cfg, toks[:, :S - 2], None, positions=jnp.arange(S - 2),
        cache=cache, enc_out=enc_out, mode="prefill",
    )
    outs = [lp[:, -1]]
    for t in range(S - 2, S):
        lt, (eo, cache), _ = encdec_apply(
            params, cfg, toks[:, t:t + 1], None,
            positions=jnp.arange(t, t + 1), cache=cache, enc_out=enc_out,
            mode="decode",
        )
        outs.append(lt[:, 0])
    dec = jnp.stack(outs, 1)
    ref = full[:, S - 3:]
    rel = float(jnp.abs(dec - ref).max()) / (
        float(jnp.abs(ref).max()) + 1e-9
    )
    assert rel < 2e-2, rel


def test_soft_moe_on_encoder():
    """Paper's technique on the (non-causal) encoder side — DESIGN.md §5."""
    cfg, params, toks, frames = _setup(soft=True)
    assert cfg.moe is not None and cfg.moe.variant == "soft"
    loss, _ = encdec_loss(params, cfg, {"tokens": toks, "embeds": frames})
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(
        lambda p: encdec_loss(p, cfg, {"tokens": toks, "embeds": frames})[0]
    )(params)
    assert all(
        bool(jnp.isfinite(g).all())
        for g in jax.tree_util.tree_leaves(grads)
    )
