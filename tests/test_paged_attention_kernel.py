"""Pallas paged decode-attention kernel vs the jnp gather oracle:
allclose attention outputs across GQA grouping, sliding windows, ragged
block tables, null-block rows, inactive (pos < 0) rows, non-default and
subdivided block sizes; full-layer and engine-level (token-for-token
greedy) parity; and the structural proof that the kernel decode program
materializes no per-row (B, blocks_per_row * block_size) KV view."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels.paged_attention_kernels import paged_decode_attend
from repro.kernels.tuning import KernelConfig, paged_config
from repro.layers.attention import (
    _attend,
    _paged_view,
    gqa_apply,
    gqa_init,
    init_paged_kv_cache,
    make_mask,
)
from repro.models import lm_init
from repro.serve import Request, ServeEngine

_PARAMS = {}


def _setup(name):
    if name not in _PARAMS:
        cfg = reduced(get_config(name))
        _PARAMS[name] = (cfg, lm_init(jax.random.PRNGKey(0), cfg))
    return _PARAMS[name]


def _random_pool(rng, num_blocks, block_size, groups, dk, dv):
    k = jnp.asarray(rng.randn(num_blocks, block_size, groups, dk),
                    jnp.float32)
    v = jnp.asarray(rng.randn(num_blocks, block_size, groups, dv),
                    jnp.float32)
    return k, v


def _ragged_tables(num_blocks, block_size, row_lens, blocks_per_row):
    """Tables + pool positions for rows of the given lengths; len < 0
    marks an inactive row (all-null table). Physical ids are assigned
    out of logical order to make aliasing bugs visible."""
    pos = np.full((num_blocks, block_size), -1, np.int32)
    tables = np.zeros((len(row_lens), blocks_per_row), np.int32)
    nxt = num_blocks - 1  # allocate top-down: physical != logical order
    for r, ln in enumerate(row_lens):
        if ln < 0:
            continue
        for lb in range(-(-ln // block_size)):
            blk, nxt = nxt, nxt - 1
            tables[r, lb] = blk
            for off in range(block_size):
                p = lb * block_size + off
                if p < ln:
                    pos[blk, off] = p
    qpos = np.asarray([ln - 1 if ln > 0 else -1 for ln in row_lens],
                      np.int32)
    return jnp.asarray(tables), jnp.asarray(pos), jnp.asarray(qpos)


def _gather_oracle(q, k_pool, v_pool, pos_pool, tables, qpos, window,
                   is_global):
    """The jnp decode path the kernel replaces: row-view gather through
    the tables, then the dense masked softmax (`_attend`)."""
    cache = {"k": k_pool, "v": v_pool, "pos": pos_pool}
    gathered, kpos = _paged_view(cache, tables)
    mask = make_mask(qpos[:, None], kpos, True, window, is_global)
    return _attend(q[:, None], gathered["k"], gathered["v"], mask)[:, 0]


CASES = [
    # (groups, heads, window, is_global, block_size)
    (2, 4, None, True, 8),     # GQA 2:1, full attention
    (1, 4, None, True, 8),     # MQA-style single kv head
    (2, 4, 6, False, 8),       # sliding-window local layer
    (2, 4, 6, True, 8),        # window config on a GLOBAL layer
    (2, 4, None, True, 6),     # non-default, non-power-of-two block size
    (4, 4, None, True, 16),    # MHA (rep=1), bigger blocks
]


@pytest.mark.parametrize("groups,heads,window,is_global,block_size", CASES)
def test_kernel_matches_gather_reference(groups, heads, window, is_global,
                                         block_size):
    rng = np.random.RandomState(0)
    dk = dv = 16
    num_blocks = 16
    blocks_per_row = 4
    # ragged: long row, short row, block-aligned row, inactive row
    row_lens = [3 * block_size + 1, 2, block_size, -1]
    k_pool, v_pool = _random_pool(rng, num_blocks, block_size, groups,
                                  dk, dv)
    tables, pos_pool, qpos = _ragged_tables(
        num_blocks, block_size, row_lens, blocks_per_row
    )
    q = jnp.asarray(rng.randn(len(row_lens), heads, dk), jnp.float32)
    out = paged_decode_attend(
        q, k_pool, v_pool, pos_pool, tables, qpos,
        causal=True, window=window, is_global=is_global,
    )
    ref = _gather_oracle(q, k_pool, v_pool, pos_pool, tables, qpos,
                         window, is_global)
    active = np.asarray(qpos) >= 0
    np.testing.assert_allclose(np.asarray(out)[active],
                               np.asarray(ref)[active],
                               rtol=1e-5, atol=1e-5)
    # inactive rows: all keys masked -> defined zeros, never NaN
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.allclose(np.asarray(out)[~active], 0.0)


def test_kernel_subdivided_pool_blocks():
    """paged_block_kv < block_size streams a pool block in several tiles
    (large --block-size pools); the recurrence must be tile-size
    invariant."""
    rng = np.random.RandomState(1)
    groups, heads, dk, block_size = 2, 4, 16, 8
    k_pool, v_pool = _random_pool(rng, 12, block_size, groups, dk, dk)
    tables, pos_pool, qpos = _ragged_tables(12, block_size, [19, 5], 3)
    q = jnp.asarray(rng.randn(2, heads, dk), jnp.float32)
    outs = [
        paged_decode_attend(
            q, k_pool, v_pool, pos_pool, tables, qpos,
            cfg=KernelConfig(paged_block_kv=bkv),
        )
        for bkv in (8, 4, 2)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6, atol=1e-6)


def test_paged_config_subdivides_large_blocks():
    assert paged_config(16).paged_block_kv == 16
    assert paged_config(512).paged_block_kv == 128
    assert paged_config(192).paged_block_kv == 96  # largest divisor <= 128
    assert paged_config(250).paged_block_kv == 125  # non-pow2 still bounded
    base = KernelConfig(paged_block_kv=32)
    assert paged_config(256, base).paged_block_kv == 32


def test_gqa_apply_paged_kernel_matches_gather():
    """Full layer parity: same paged cache, same block tables — the
    kernel path's decode output and updated cache match the gather
    path's (the cache write is shared; only the attend differs)."""
    cfg, _ = _setup("llama3-8b")
    params = gqa_init(jax.random.PRNGKey(3), cfg)
    block_size, nb = 8, 4
    cache = init_paged_kv_cache(cfg, 12, block_size, dtype=jnp.float32)
    tables = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], np.int32)
    rng = np.random.RandomState(2)
    # prefill both rows through the (shared) gather path: S > 1 chunk
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model), jnp.float32)
    poss = jnp.asarray(
        [np.arange(8), [-1] * 5 + [0, 1, 2]], np.int32
    )  # row1: left-padded short prompt
    _, cache = gqa_apply(params, cfg, x, positions=poss, cache=cache,
                         mode="decode", block_tables=tables)
    xd = jnp.asarray(rng.randn(2, 1, cfg.d_model), jnp.float32)
    dpos = jnp.asarray([[8], [3]], np.int32)
    outs, caches = [], []
    for pk in (False, True):
        o, c = gqa_apply(params, cfg, xd, positions=dpos, cache=cache,
                         mode="decode", block_tables=tables,
                         paged_kernel=pk)
        outs.append(np.asarray(o))
        caches.append(c)
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)
    for name in caches[0]:
        np.testing.assert_array_equal(np.asarray(caches[0][name]),
                                      np.asarray(caches[1][name]))


# llama3 = dense GQA, gemma3 = sliding-window local:global,
# qwen2 = QKV bias; mamba2/MLA have no GQA kernel path by design.
@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-27b", "qwen2-0.5b"])
def test_engine_greedy_parity_kernel_vs_gather(arch):
    """ServeEngine(paged, use_kernel=True) produces token-for-token the
    greedy streams of the jnp-gather oracle engine under slot/block churn
    (ragged prompts, mixed lengths)."""
    cfg, params = _setup(arch)
    prompts = [[1, 2, 3, 4, 5], [9, 8, 7], [4] * 9, [5, 6] * 5, [2]]
    outs = []
    for uk in (False, True):
        eng = ServeEngine(cfg, params, batch_size=2, max_len=48,
                          backend="paged", block_size=8, use_kernel=uk)
        reqs = [Request(prompt=list(p), max_new_tokens=5) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]


def test_kernel_decode_jaxpr_has_no_row_view():
    """The point of the kernel: the paged decode program's jaxpr carries
    no (B, blocks_per_row * block_size) tensor while the gather oracle
    materializes one. The proof lives in the benchmark (it is also a CI
    job); this just pins it into tier-1."""
    from benchmarks.bench_kernels import check_paged_materialization

    check_paged_materialization(verbose=False)
