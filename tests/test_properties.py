"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import MoEConfig
from repro.core import moe_init, soft_moe_weights
from repro.kernels import ref
from repro.layers.common import l2_normalize
from repro.models.lm import cross_entropy
from repro.optim import compress_with_feedback, dequantize_int8, quantize_int8

_settings = settings(max_examples=25, deadline=None)


@given(
    m=st.integers(2, 24),
    d=st.integers(2, 24),
    n=st.integers(1, 6),
    p=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
@_settings
def test_soft_moe_weights_are_proper_distributions(m, d, n, p, seed):
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (1, m, d))
    cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=4,
                    slots_per_expert=p)
    params = moe_init(rng, d, cfg)
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    # D columns (over tokens) and C rows (over slots) are simplexes
    np.testing.assert_allclose(np.asarray(d_w.sum(1)), 1.0, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c_w.sum((2, 3))), 1.0, rtol=1e-4)
    assert bool((d_w >= 0).all()) and bool((c_w >= 0).all())


@given(
    m=st.integers(1, 32), d=st.integers(1, 48), seed=st.integers(0, 2**16)
)
@_settings
def test_l2_normalize_unit_or_zero(m, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    y = l2_normalize(x, axis=1)
    norms = np.asarray(jnp.linalg.norm(y, axis=1))
    assert ((np.abs(norms - 1.0) < 1e-3) | (norms < 1e-3)).all()


@given(
    b=st.integers(1, 4), s=st.integers(2, 16), v=st.integers(2, 50),
    seed=st.integers(0, 2**16),
)
@_settings
def test_cross_entropy_matches_log_softmax(b, s, v, seed):
    rng = jax.random.PRNGKey(seed)
    logits = 5.0 * jax.random.normal(rng, (b, s, v))
    targets = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, v)
    got = cross_entropy(logits, targets)
    lp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.take_along_axis(lp, targets[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@given(
    n=st.integers(1, 300), scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**16),
)
@_settings
def test_int8_quantization_error_bound(n, scale, seed):
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert (err <= float(s) / 2 + 1e-6).all()  # round-to-nearest bound


@given(seed=st.integers(0, 2**16))
@_settings
def test_error_feedback_drives_accumulated_error_down(seed):
    """Summing EF-compressed copies of a constant gradient converges to
    the true sum: the residual never accumulates (contractive EF)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(20):
        q, s, err = compress_with_feedback(g, err)
        total = total + dequantize_int8(q, s)
    avg = total / 20
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.01 + 1e-5)


@given(
    m=st.integers(2, 16), d=st.integers(4, 32), s=st.integers(2, 16),
    seed=st.integers(0, 2**16),
)
@_settings
def test_dispatch_ref_convexity(m, d, s, seed):
    """Slots are convex combinations of tokens: each slot lies inside the
    per-dimension [min, max] envelope of the token set."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, s))
    slots = ref.dispatch_ref(x, ref.normalized_phi(phi, 1.0))
    lo = np.asarray(x.min(0)) - 1e-4
    hi = np.asarray(x.max(0)) + 1e-4
    sl = np.asarray(slots)
    assert (sl >= lo).all() and (sl <= hi).all()
