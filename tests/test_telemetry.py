"""Model-interior serving telemetry (serve/telemetry.py + the telemetry
program variants): the side outputs must be free — bit-identical served
tokens, zero extra recompiles — and correct — routing stats agreeing
with the core/inspection.py dense oracle; the batch-variance probe must
read finite exactly where routing is batch-coupled."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.inspection import routing_stats
from repro.core.soft_moe import soft_moe_apply, soft_moe_init
from repro.models import lm_init
from repro.serve import (
    Request,
    SamplingParams,
    ServeEngine,
    ServeMetrics,
    batch_variance_probe,
    parse_prometheus,
    render_prometheus,
)


def _moe_setup(name="granite-moe-1b-a400m", **moe_over):
    cfg = reduced(get_config(name))
    if moe_over:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, telemetry, backend="contiguous", sampled=False):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      backend=backend, telemetry=telemetry)
    sp = (SamplingParams(temperature=0.9, top_k=20, seed=7) if sampled
          else SamplingParams())
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=6, sampling=sp),
            Request(prompt=[9, 8, 7], max_new_tokens=6, sampling=sp)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.out for r in reqs]


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
@pytest.mark.parametrize("sampled", [False, True])
def test_telemetry_token_parity(backend, sampled):
    """Telemetry on must serve BIT-IDENTICAL tokens — greedy and
    sampled, both cache backends. The stats are stop_gradient'd side
    outputs; any influence on the sampled path is a bug."""
    cfg, params = _moe_setup()
    _, off = _serve(cfg, params, False, backend, sampled)
    eng, on = _serve(cfg, params, True, backend, sampled)
    assert on == off
    # and the stats actually populated
    snap = eng.telemetry_snapshot()
    assert "decode" in snap and "prefill" in snap
    assert any(k.startswith("moe_") for k in snap["decode"])
    assert all(np.isfinite(v) for v in snap["decode"].values())


def test_telemetry_zero_recompiles_under_churn():
    """The telemetry flag is static: after warmup, serving more churny
    traffic with telemetry on must not grow any jit cache."""
    cfg, params = _moe_setup()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      telemetry=True)
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
    eng.run()
    warm = eng.jit_cache_sizes()
    reqs = [Request(prompt=[i + 1] * (3 + i % 5), max_new_tokens=3 + i % 4)
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.jit_cache_sizes() == warm


def test_soft_moe_telemetry_matches_dense_oracle():
    """The telemetry scalars the serving path emits (computed from the
    kernel's saved softmax stats) must agree with the materializing
    dense oracle in core/inspection.py on the same inputs."""
    rng = jax.random.PRNGKey(3)
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    moe = dataclasses.replace(cfg.moe, variant="soft")
    d = cfg.d_model
    params = soft_moe_init(jax.random.PRNGKey(1), d, moe)
    x = jax.random.normal(rng, (2, 16, d), jnp.float32)

    oracle = routing_stats(x, params, method="dense")
    for use_kernel in (False, True):
        _, m = soft_moe_apply(params, moe, x, use_kernel=use_kernel,
                              telemetry=True)
        t = m["telemetry"]
        for tk, ok in (("dispatch_entropy", "dispatch_entropy"),
                       ("combine_entropy", "combine_entropy"),
                       ("token_contribution_min", "token_contribution_min"),
                       ("token_contribution_max", "token_contribution_max"),
                       ("max_dispatch", "max_dispatch_weight"),
                       ("max_combine", "max_combine_weight")):
            np.testing.assert_allclose(
                np.asarray(t[tk]), np.asarray(oracle[ok]), rtol=2e-5,
                atol=2e-5, err_msg=f"{tk} (use_kernel={use_kernel})")


def test_batch_variance_probe_null_on_group_routed_sparse():
    """THE batch-invariant-serving acceptance criterion: even the
    historically worst case — group-routed BPR tokens-choice with
    binding capacity — must read ~0, because serving modes route each
    row alone and droplessly (group/capacity knobs only bind in train
    mode). ~0 on dense too (no routing at all)."""
    cfg, params = _moe_setup(group_size=4, capacity_factor=0.5, bpr=True)
    grouped = batch_variance_probe(cfg, params, [1, 2, 3, 4], batch_size=4,
                                   max_new_tokens=8, max_len=32)
    assert grouped["steps_compared"] > 0
    assert grouped["divergence"] < 1e-5

    dcfg = reduced(get_config("llama3-8b"))
    dparams = lm_init(jax.random.PRNGKey(0), dcfg)
    dense = batch_variance_probe(dcfg, dparams, [1, 2, 3, 4], batch_size=4,
                                 max_new_tokens=8, max_len=32)
    assert dense["steps_compared"] > 0
    assert dense["divergence"] < 1e-5


def test_batch_variance_probe_instrument_alive_via_escape_hatch():
    """The ~0 readings above must be the routing's doing, not a dead
    probe: forcing the old batch-coupled group routing at serving
    (MoEConfig.batch_coupled=True) with BPR + binding capacity must
    read FINITE divergence — capacity competition reaches the target
    row again."""
    cfg, params = _moe_setup(group_size=4, capacity_factor=0.5, bpr=True,
                             batch_coupled=True)
    coupled = batch_variance_probe(cfg, params, [1, 2, 3, 4], batch_size=4,
                                   max_new_tokens=8, max_len=32)
    assert coupled["steps_compared"] > 0
    assert coupled["divergence"] > 0


def test_batch_variance_probe_null_on_soft_moe():
    """Soft MoE's softmaxes are per-sequence (the paper's §3.5 point):
    the probe must read ~0 even though it IS a MoE."""
    cfg, params = _moe_setup(variant="soft")
    res = batch_variance_probe(cfg, params, [1, 2, 3, 4], batch_size=3,
                               max_new_tokens=6, max_len=32)
    assert res["steps_compared"] > 0
    assert res["divergence"] < 1e-5


def test_metrics_reset_counters():
    m = ServeMetrics()
    m.inc("submitted", 3)
    m.observe("ttft_s", 0.5)
    m.set_gauge("model_decode_foo", 1.5)
    m.reset_counters()
    assert m.count("submitted") == 0
    assert not m.series and not m.gauges
    m.inc("submitted")  # surface still usable after reset
    assert m.count("submitted") == 1


def test_gauge_exporter_round_trip():
    """Gauges (plain and labeled) must survive the strict parser; names
    may not collide with the suffix-classified counter/histogram space."""
    m = ServeMetrics()
    m.set_gauge("moe_decode_l2_router_entropy", 1.25)
    m.set_gauge("program_efficiency", 0.4375, program="decode")
    m.set_gauge("program_efficiency", 0.25, program="verify")
    with pytest.raises(AssertionError):
        m.set_gauge("bad_gauge_total", 1.0)
    text = render_prometheus(m)
    parsed = parse_prometheus(text)
    assert parsed["gauges"]["repro_serve_moe_decode_l2_router_entropy"] == (
        {}, 1.25)
    # labeled variants share a name; the parser keeps the last sample,
    # which must still be one of the rendered label sets
    labels, value = parsed["gauges"]["repro_serve_program_efficiency"]
    assert labels["program"] in ("decode", "verify")
    assert value in (0.4375, 0.25)


def test_engine_program_efficiency_populates():
    cfg, params = _moe_setup()
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      telemetry=True)
    eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
    eng.run()
    eff = eng.program_efficiency()
    assert "decode" in eff and eff["decode"] > 0
    assert all(np.isfinite(v) for v in eff.values())
