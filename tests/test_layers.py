"""Layer substrate: attention paths (dense == chunked/flash, MLA, sliding
window), rotary, norms, SSD == sequential recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig
from repro.layers import attention as attn
from repro.layers.common import l2_normalize, norm_apply, norm_init
from repro.layers.rotary import apply_rope
from repro.layers.ssm import ssd_chunked, ssd_decode_step


def test_chunked_attention_matches_dense():
    rng = jax.random.PRNGKey(0)
    b, sq, h, g, d = 2, 64, 8, 2, 32
    q = jax.random.normal(rng, (b, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sq, g, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sq, g, d))
    pos = jnp.arange(sq)
    for causal in (True, False):
        for window in (None, 16):
            mask = attn.make_mask(pos, pos, causal, window)[None]
            dense = attn._attend(q, k, v, mask)
            chunk = attn._attend_chunked(q, k, v, pos, pos, causal, window,
                                         block=16)
            np.testing.assert_allclose(
                np.asarray(dense), np.asarray(chunk), rtol=2e-5, atol=2e-5
            )


def test_chunked_attention_mla_vdim():
    """Different value dim (MLA latent path) through the chunked kernel."""
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, 16, 4, 24))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 1, 24))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 1, 8))
    pos = jnp.arange(16)
    mask = attn.make_mask(pos, pos, True, None)[None]
    dense = attn._attend(q, k, v, mask, scale=0.3)
    chunk = attn._attend_chunked(q, k, v, pos, pos, True, None, scale=0.3,
                                 block=4)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunk),
                               rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relative_positions():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (1, 8, 2, 16))
    y = apply_rope(x, jnp.arange(8), 10000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([i]), 1e4)
        kj = apply_rope(k, jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_sliding_window_mask():
    pos = jnp.arange(8)
    m = attn.make_mask(pos, pos, True, 3, is_global=False)
    m = np.asarray(m)
    assert m[5, 5] and m[5, 4] and m[5, 3]
    assert not m[5, 2]  # outside window
    assert not m[2, 5]  # future
    # global flag disables the window
    mg = np.asarray(attn.make_mask(pos, pos, True, 3, is_global=True))
    assert mg[5, 0]


def test_norms():
    for norm in ("rmsnorm", "layernorm"):
        cfg = ModelConfig(norm=norm)
        p = norm_init(cfg, 32)
        x = 3.0 + 2.0 * jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        y = norm_apply(p, cfg, x)
        if norm == "layernorm":
            np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-2)
        np.testing.assert_allclose(
            np.asarray(jnp.sqrt((y.astype(jnp.float32) ** 2).mean(-1))),
            1.0, atol=5e-2,
        )


def test_l2_normalize_unit_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 16))
    y = l2_normalize(x, axis=1)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=1)), 1.0, rtol=1e-4
    )


def _ssd_sequential(x, dt, A, B, C):
    """Token-by-token recurrence oracle for SSD."""
    b, s, h, dh = x.shape
    state = jnp.zeros((b, h, dh, B.shape[-1]))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            x[:, t:t+1], dt[:, t:t+1], A, B[:, t:t+1], C[:, t:t+1], state
        )
        ys.append(y[:, 0])
    return jnp.stack(ys, 1), state


def test_ssd_chunked_matches_sequential():
    rng = jax.random.PRNGKey(0)
    b, s, h, dh, n = 2, 24, 4, 8, 16
    x = jax.random.normal(rng, (b, s, h, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    B = jax.random.normal(jax.random.PRNGKey(3), (b, s, 1, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (b, s, 1, n))
    for chunk in (8, 6):  # divisible and ragged (padding path)
        y_c, st_c = ssd_chunked(x, dt, A, B, C, chunk)
        y_s, st_s = _ssd_sequential(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_s),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_continuation():
    """Chunked prefill then decode continues the same recurrence."""
    rng = jax.random.PRNGKey(0)
    b, s, h, dh, n = 1, 16, 2, 4, 8
    x = jax.random.normal(rng, (b, s, h, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    B = jax.random.normal(jax.random.PRNGKey(3), (b, s, 1, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (b, s, 1, n))
    y_full, st_full = ssd_chunked(x, dt, A, B, C, 8)
    y1, st1 = ssd_chunked(x[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], 8)
    y2, st2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:], 8,
                          initial_state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


def test_mla_decode_matches_train():
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    from repro.layers.attention import attention_init, attention_apply, init_kv_cache
    params = attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    full, _ = attention_apply(params, cfg, x, positions=jnp.arange(12))
    cache = init_kv_cache(cfg, 2, 12, True)
    out_p, cache = attention_apply(
        params, cfg, x[:, :8], positions=jnp.arange(8), cache=cache,
        mode="prefill",
    )
    outs = [out_p]
    for t in range(8, 12):
        o, cache = attention_apply(
            params, cfg, x[:, t:t+1], positions=jnp.arange(t, t+1),
            cache=cache, mode="decode",
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=0.1, atol=0.05,
    )
