"""Contract-linter tests (src/repro/analysis/, docs/static_analysis.md).

Two halves:

* falsifiability — every pass flags a deliberately-bad fixture (a
  materializing ref-path program, a shape-dependent retrace, a
  non-donating pool program, a silent upcast/downcast, a syncing tick
  loop). A linter that cannot fail proves nothing.
* the real stack — one small arch's full program inventory runs every
  pass clean modulo the reasoned allowlist, and the bench wrappers
  still route through the one framework walker.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    DEFAULT_ALLOWLIST,
    AllowRule,
    ProgramSpec,
    ShapeRule,
    apply_allowlist,
    arg_signature,
    host_purity_findings,
    run_passes,
)
from repro.analysis.passes import (
    donation_pass,
    dtype_pass,
    materialization_pass,
    retrace_pass,
)

# ---------------------------------------------------------------------------
# materialization
# ---------------------------------------------------------------------------

_M, _D, _N, _B = 48, 32, 8, 2  # pairwise-distinct marker dims


def _moe_grad_spec(use_kernel: bool) -> ProgramSpec:
    from repro.configs.base import MoEConfig
    from repro.core import moe_apply, moe_init
    from repro.kernels.tuning import config_from_moe

    cfg = MoEConfig(variant="soft", num_experts=_N, expert_d_ff=24)
    s = _N * cfg.slots_per_expert
    params = moe_init(jax.random.PRNGKey(0), _D, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (_B, _M, _D))
    kc = config_from_moe(cfg, m=_M, d=_D)
    m_pad = -(-_M // kc.block_tokens) * kc.block_tokens
    s_pad = -(-s // kc.block_slots) * kc.block_slots

    def loss(p):
        return (moe_apply(p, cfg, x, use_kernel=use_kernel)[0] ** 2).mean()

    rule = ShapeRule((_M, m_pad), (s, s_pad), "(m × s) plane")
    name = "kernel" if use_kernel else "ref"
    return ProgramSpec(f"fixture/moe_grad_{name}", "test",
                       jax.grad(loss), (params,), forbid=(rule,))


def test_materialization_flags_ref_path():
    # the jnp reference path materializes the (m × s) logits/weights —
    # the known-bad construct the fused kernels exist to eliminate
    findings, n = materialization_pass([_moe_grad_spec(use_kernel=False)])
    assert n == 1
    assert findings and "(m × s) plane" in findings[0].message


def test_materialization_clean_on_kernel_path():
    # uses the bench geometry (m=320, s=48, blocks 128): at the fixture's
    # tiny dims the kernel's (block_tokens × block_slots) tile IS the
    # whole plane, so only a multi-tile geometry can witness cleanliness
    from repro.analysis import kernel_program_specs

    spec = next(s for s in kernel_program_specs()
                if s.name == "kernels/soft_moe_grad")
    findings, n = materialization_pass([spec])
    assert n == 1 and findings == []


def test_materialization_skips_specs_without_rules():
    spec = ProgramSpec("fixture/norule", "test",
                       lambda x: x + 1, (jnp.zeros(3),))
    findings, n = materialization_pass([spec])
    assert n == 0 and findings == []


# ---------------------------------------------------------------------------
# retrace
# ---------------------------------------------------------------------------


def _pad_to_multiple(ids, mult):
    n = -(-len(ids) // mult) * mult
    out = np.zeros((n,), np.int32)
    out[: len(ids)] = ids
    return jnp.asarray(out)


def test_retrace_flags_shape_dependent_program():
    # a "pad to the next multiple" helper whose width follows the id
    # count — exactly the churn-dependent shape the fixed-width
    # _pad_ids batching in serve/block_manager.py exists to avoid
    spec = ProgramSpec(
        "fixture/bad_pad", "test", lambda ids: ids * 2,
        (_pad_to_multiple(np.arange(3), 4),),
        churn=((_pad_to_multiple(np.arange(11), 4),),),
    )
    findings, n = retrace_pass([spec])
    assert n == 1
    assert findings and "recompile" in findings[0].message


def test_retrace_clean_on_fixed_shapes():
    spec = ProgramSpec(
        "fixture/good_pad", "test", lambda ids: ids * 2,
        (jnp.zeros((8,), jnp.int32),),
        churn=((jnp.ones((8,), jnp.int32),),),
    )
    findings, n = retrace_pass([spec])
    assert n == 1 and findings == []


def test_arg_signature_distinguishes_weak_scalars():
    assert arg_signature((1.0,)) != arg_signature((jnp.float32(1.0),))


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


def _pool_like():
    return [{"attn": {"k": jnp.zeros((2, 4)), "pos": jnp.zeros((2, 4))}}]


def _scrub(cache, slot):
    return jax.tree_util.tree_map(lambda a: a * 0, cache)


def test_donation_flags_non_donating_pool_program():
    spec = ProgramSpec("fixture/undonated", "test", jax.jit(_scrub),
                       (_pool_like(), jnp.int32(0)), donate=(0,))
    findings, n = donation_pass([spec])
    assert n == 1
    assert findings and "not donated" in findings[0].message


def test_donation_clean_when_donated():
    spec = ProgramSpec(
        "fixture/donated", "test",
        jax.jit(_scrub, donate_argnums=(0,)),
        (_pool_like(), jnp.int32(0)), donate=(0,),
    )
    findings, n = donation_pass([spec])
    assert n == 1 and findings == []


def test_donation_flags_unjitted_program():
    spec = ProgramSpec("fixture/plain", "test", _scrub,
                       (_pool_like(), jnp.int32(0)), donate=(0,))
    findings, _ = donation_pass([spec])
    assert findings and "not jitted" in findings[0].message


# ---------------------------------------------------------------------------
# dtype
# ---------------------------------------------------------------------------


def test_dtype_flags_bf16_accumulation_downcast():
    # jnp.sum auto-upcasts bf16 accumulation, so the bad fixture must
    # reach for the lax-level reduce the upcast machinery doesn't wrap
    def bad(x):
        return jax.lax.reduce(x.astype(jnp.bfloat16),
                              jnp.bfloat16(0), jax.lax.add, (0,))

    spec = ProgramSpec("fixture/bf16_sum", "test", bad,
                       (jnp.zeros((4, 3)),), acc_dtype="float32")
    findings, n = dtype_pass([spec])
    assert n == 1
    assert findings and "downcast" in findings[0].message


def test_dtype_flags_silent_f32_upcast():
    # declared bf16 accumulation, actual f32 reductions: the "silent
    # upcast" direction — costs memory/bandwidth the config says it
    # shouldn't spend
    spec = ProgramSpec("fixture/f32_sum", "test",
                       lambda x: jnp.sum(x, axis=0),
                       (jnp.zeros((4, 3), jnp.float32),),
                       acc_dtype="bfloat16")
    findings, n = dtype_pass([spec])
    assert n == 1
    assert findings and "upcast" in findings[0].message


def test_dtype_clean_on_declared_acc():
    def ok(x):
        acc = jnp.sum(x.astype(jnp.float32), axis=0)
        return acc.astype(jnp.bfloat16)

    spec = ProgramSpec("fixture/f32_acc", "test", ok,
                       (jnp.zeros((4, 3), jnp.bfloat16),),
                       acc_dtype="float32")
    findings, n = dtype_pass([spec])
    assert n == 1 and findings == []


def test_dtype_dots_only_policy_skips_reductions():
    def bwd_like(x):
        return jnp.sum(x.astype(jnp.bfloat16), axis=0)

    spec = ProgramSpec("fixture/bwd", "test", bwd_like,
                       (jnp.zeros((4, 3)),), acc_dtype="float32",
                       dtype_policy="dots_only")
    findings, n = dtype_pass([spec])
    assert n == 1 and findings == []


# ---------------------------------------------------------------------------
# host-purity
# ---------------------------------------------------------------------------

_BAD_TICK = '''\
import jax

JITTED = jax.jit(lambda x: x + 1)           # import-scope jit
INTERPRET = jax.default_backend() != "tpu"  # import-time backend global


@jax.jit
def decorated(x):                            # import-scope jit, decorator
    return x


class Engine:
    def tick(self):
        v = self.logits.item()               # host sync in the tick loop
        jax.device_get(self.state)           # host sync
        self.out.block_until_ready()         # host sync
'''


def test_host_purity_flags_syncing_tick_loop(tmp_path):
    p = tmp_path / "bad_engine.py"
    p.write_text(_BAD_TICK)
    findings = host_purity_findings([str(p)])
    msgs = "\n".join(f.message for f in findings)
    assert sum("host sync" in f.message for f in findings) == 3
    assert "jax.jit at import scope" in msgs
    assert "decorator" in msgs
    assert "freezes the backend choice" in msgs


def test_host_purity_clean_file(tmp_path):
    p = tmp_path / "good_engine.py"
    p.write_text(
        "import jax\n\n\n"
        "def build(cfg):\n"
        "    interpret = jax.default_backend() != 'tpu'\n"
        "    return jax.jit(lambda x: x + 1), interpret\n"
    )
    assert host_purity_findings([str(p)]) == []


def test_host_purity_repo_clean_modulo_allowlist():
    report = run_passes([], ["host-purity"], DEFAULT_ALLOWLIST)
    assert report.ok(), report.render()
    # the sanctioned syncs are RECORDED, not invisible
    assert any("telemetry" in f.where for f in report.allowed)


# ---------------------------------------------------------------------------
# allowlist mechanics
# ---------------------------------------------------------------------------


def test_allowlist_matches_and_keeps_reason():
    from repro.analysis import Finding

    f = Finding("donation", "engine/sample@llama3-8b", "not donated")
    out = apply_allowlist(
        [f], [AllowRule("donation", "engine/sample@*", "by design")]
    )
    assert out[0].allowed and out[0].reason == "by design"
    g = Finding("dtype", "engine/sample@llama3-8b", "x")
    assert not apply_allowlist(
        [g], [AllowRule("donation", "engine/sample@*", "r")]
    )[0].allowed


def test_unknown_pass_rejected():
    with pytest.raises(KeyError):
        run_passes([], ["nonesuch"])


# ---------------------------------------------------------------------------
# the real stack: one small arch end to end
# ---------------------------------------------------------------------------


def test_serving_stack_passes_on_small_arch():
    from repro.analysis import build_program_specs

    specs = build_program_specs("qwen2-0.5b", train=False)
    report = run_passes(
        specs, ["materialization", "retrace", "donation", "dtype"],
        DEFAULT_ALLOWLIST,
    )
    assert report.ok(), report.render()
    # the inventory is the real thing: paged decode + donation checked
    assert report.checked["donation"] >= 10
    assert any(s.name == "paged/decode" and s.forbid for s in specs)


def test_trainer_step_donates_state():
    from repro.analysis import train_program_spec

    spec = train_program_spec("qwen2-0.5b")[0]
    findings, n = donation_pass([spec])
    assert n == 1 and findings == [], findings


# ---------------------------------------------------------------------------
# bench wrappers delegate to the framework walker
# ---------------------------------------------------------------------------


def test_bench_wrapper_routes_through_framework():
    import sys

    sys.path.insert(0, ".")
    try:
        from benchmarks.bench_kernels import materialized_ms_shapes
    finally:
        sys.path.pop(0)

    def outer(a, b):
        return a @ b  # (5, 9) product plane

    shapes = materialized_ms_shapes(
        outer, jnp.zeros((5, 7)), jnp.zeros((7, 9)), m=5, s=9
    )
    assert (5, 9) in shapes

    def clean(a):
        return a.sum()

    assert materialized_ms_shapes(clean, jnp.zeros((5, 7)), m=5, s=9) == []
