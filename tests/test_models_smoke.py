"""Per-arch smoke tests (deliverable f): every assigned architecture at a
REDUCED same-family config runs one forward/train step on CPU with correct
output shapes and no NaNs; decode consistency vs the train path."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_NAMES, get_config, reduced, softify
from repro.models import build_model, init_cache, lm_apply, lm_init


def _batch_for(cfg, rng, b=2, s=32):
    if cfg.family == "vit":
        return {
            "patches": jax.random.normal(
                rng, (b, cfg.frontend.num_embeds, cfg.frontend.embed_dim)
            ),
            "labels": jax.random.randint(rng, (b,), 0, 1000),
        }
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend.kind != "none":
        batch["embeds"] = jax.random.normal(
            rng, (b, cfg.frontend.num_embeds, cfg.frontend.embed_dim)
        )
    return batch


@pytest.mark.parametrize("name", ASSIGNED_NAMES)
def test_arch_train_step(name):
    cfg = reduced(get_config(name))
    init, loss_fn, _ = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = init(rng)
    batch = _batch_for(cfg, rng)
    (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch
    )
    assert bool(jnp.isfinite(l)), f"{name}: non-finite loss"
    assert float(l) > 0
    finite = all(
        bool(jnp.isfinite(g).all()) for g in jax.tree_util.tree_leaves(grads)
    )
    assert finite, f"{name}: non-finite grads"


@pytest.mark.parametrize("name", ASSIGNED_NAMES)
def test_arch_forward_shapes(name):
    cfg = reduced(get_config(name))
    init, _, apply_fn = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = init(rng)
    batch = _batch_for(cfg, rng)
    out = apply_fn(params, batch)
    logits = out[0]
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaNs in logits"
    if cfg.family == "vit":
        assert logits.shape == (2, 1000)
    else:
        assert logits.shape[0] == 2
        assert logits.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize(
    "name",
    [n for n in ASSIGNED_NAMES if get_config(n).encoder_layers == 0],
)
def test_arch_decode_consistency(name):
    """prefill + token-by-token decode == full forward (sparse-MoE archs
    are checked with slack capacity: tight capacity legitimately makes
    routing batch-dependent — paper §2.2)."""
    cfg = reduced(get_config(name))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    params = lm_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    embeds = None
    if cfg.frontend.kind != "none":
        embeds = jax.random.normal(
            rng, (B, cfg.frontend.num_embeds, cfg.frontend.embed_dim)
        )
    full, _, _ = lm_apply(params, cfg, toks, embeds=embeds, mode="train")
    split = S - 4
    n_prefix = full.shape[1] - S
    cache = init_cache(cfg, B, S + n_prefix)
    lp, cache, _ = lm_apply(
        params, cfg, toks[:, :split], embeds=embeds,
        positions=jnp.arange(split + n_prefix), cache=cache, mode="prefill",
    )
    outs = [lp[:, -1]]
    for t in range(split, S):
        lt, cache, _ = lm_apply(
            params, cfg, toks[:, t:t + 1],
            positions=jnp.arange(n_prefix + t, n_prefix + t + 1),
            cache=cache, mode="decode",
        )
        outs.append(lt[:, 0])
    dec = jnp.stack(outs, 1)
    ref = full[:, n_prefix + split - 1:]
    err = float(jnp.abs(dec - ref).max())
    rel = err / (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 2e-2, f"{name}: decode mismatch rel={rel:.3e}"


def test_softified_variants_train():
    """The paper's technique as a first-class config option (`+soft`)."""
    for name in ("llama3-8b", "deepseek-v2-lite-16b", "granite-moe-1b-a400m"):
        cfg = reduced(get_config(name + "+soft"))
        assert cfg.moe is not None and cfg.moe.variant == "soft"
        init, loss_fn, _ = build_model(cfg)
        params = init(jax.random.PRNGKey(0))
        batch = _batch_for(cfg, jax.random.PRNGKey(1))
        l, _ = loss_fn(params, batch)
        assert bool(jnp.isfinite(l))


def test_softify_rejects_mlp_free_arch():
    with pytest.raises(ValueError):
        softify(get_config("mamba2-370m"))


def test_paper_vit_models_train():
    from repro.configs import soft_moe_vit

    cfg = reduced(soft_moe_vit("s", 16, 8))
    init, loss_fn, _ = build_model(cfg)
    params = init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    l, metrics = loss_fn(params, batch)
    assert bool(jnp.isfinite(l))
