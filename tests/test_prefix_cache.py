"""Radix prefix-cache invariants: insert/lookup round trips, the
last-token cap, dedup, LRU leaf-first eviction under pressure, and the
pin rule (blocks under live requests are unevictable)."""
from repro.serve.block_manager import BlockManager
from repro.serve.prefix_cache import RadixPrefixCache

BS = 4


def _prompt(*blocks):
    out = []
    for b in blocks:
        out.extend([b * 100 + i for i in range(BS)])
    return out


def _insert_chain(tree, mgr, tokens):
    n_full = len(tokens) // BS
    blocks = mgr.alloc(n_full)
    tree.insert(tokens[: n_full * BS], blocks, mgr)
    return blocks


def test_insert_then_match_roundtrip():
    mgr = BlockManager(32)
    tree = RadixPrefixCache(BS)
    toks = _prompt(1, 2, 3)
    blocks = _insert_chain(tree, mgr, toks)
    assert all(mgr.ref[b] == 2 for b in blocks)  # request + tree
    # same prompt + one extra token: full chain matches
    assert tree.match(toks + [999]) == blocks
    # shared two-block prefix, divergent third block
    assert tree.match(_prompt(1, 2, 9) + [7]) == blocks[:2]
    # cold prompt: nothing
    assert tree.match(_prompt(8, 9) + [1]) == []


def test_match_never_covers_last_token():
    """At least one prompt token must re-run (the engine needs logits for
    the final position), so a prompt that IS a cached chain matches only
    its first blocks."""
    mgr = BlockManager(32)
    tree = RadixPrefixCache(BS)
    toks = _prompt(1, 2)
    blocks = _insert_chain(tree, mgr, toks)
    assert tree.match(toks) == blocks[:1]  # last block excluded
    assert tree.match(toks[: BS + 1]) == blocks[:1]
    assert tree.match(toks[:BS]) == []  # whole prompt inside block 0


def test_insert_dedups_keeps_incumbent():
    mgr = BlockManager(32)
    tree = RadixPrefixCache(BS)
    toks = _prompt(1, 2)
    first = _insert_chain(tree, mgr, toks)
    dup = mgr.alloc(2)  # a second request prefilled the same prompt
    adopted = tree.insert(toks, dup, mgr)
    assert adopted == 0  # incumbents kept
    assert tree.match(toks + [5]) == first
    assert all(mgr.ref[b] == 1 for b in dup)  # dup stays request-owned


def test_lru_eviction_leaf_first_under_pressure():
    mgr = BlockManager(16)
    tree = RadixPrefixCache(BS)
    chain = _insert_chain(tree, mgr, _prompt(1, 2, 3))
    other = _insert_chain(tree, mgr, _prompt(7))
    # release the requests' own refs: tree is now sole owner of all
    for b in chain + other:
        mgr.decref(b)
    # touch the deep chain so `other` is LRU
    tree.match(_prompt(1, 2, 3) + [0])
    assert tree.evict_one(mgr)
    assert mgr.ref[other[0]] == 0  # LRU leaf went first
    # chain evicts tail-first: 3, then 2, then 1
    for expect in (chain[2], chain[1], chain[0]):
        assert tree.evict_one(mgr)
        assert mgr.ref[expect] == 0
    assert not tree.evict_one(mgr)  # empty
    assert len(tree) == 0
    assert mgr.num_used == 0


def test_pinned_blocks_unevictable():
    """A chain matched by a live request (refcount >= 2) must survive any
    amount of eviction pressure."""
    mgr = BlockManager(16)
    tree = RadixPrefixCache(BS)
    chain = _insert_chain(tree, mgr, _prompt(1, 2))
    for b in chain:
        mgr.decref(b)  # tree sole owner
    hit = tree.match(_prompt(1, 2) + [9])
    for b in hit:
        mgr.incref(b)  # live request pins the match
    assert tree.evict_one(mgr) is False or mgr.ref[chain[0]] >= 2
    # drain everything evictable; the pinned block must remain
    tree.evict_all_unreferenced(mgr)
    assert mgr.ref[chain[0]] >= 1
    assert tree.match(_prompt(1, 9) + [0]) == chain[:1]  # still cached


def test_eviction_under_allocation_pressure_frees_enough():
    """The backend's loop: evict until alloc fits. 6 usable blocks, a
    4-block cold tree, a 4-block allocation must succeed after evicting."""
    mgr = BlockManager(7)
    tree = RadixPrefixCache(BS)
    chain = _insert_chain(tree, mgr, _prompt(1, 2, 3, 4))
    for b in chain:
        mgr.decref(b)
    assert mgr.num_free == 2
    while not mgr.can_alloc(4):
        assert tree.evict_one(mgr)
    got = mgr.alloc(4)
    assert len(got) == 4


def test_eviction_order_is_lru_over_many_chains():
    """Regression for the O(log n) lazy-heap eviction (was an O(tree)
    rescan per evicted block): with many chains touched in a scrambled
    order, evict_one must free blocks in exact last-touch order,
    skip pinned leaves, and come back to them once the pin drops."""
    mgr = BlockManager(64)
    tree = RadixPrefixCache(BS)
    chains = {i: _insert_chain(tree, mgr, _prompt(10 + i))[0]
              for i in range(8)}
    for b in chains.values():
        mgr.decref(b)  # tree sole owner
    order = [3, 5, 0, 7, 2, 6, 1, 4]  # touch order = expected evict order
    for i in order:
        tree.match(_prompt(10 + i) + [0])
    for i in (3, 5):  # pin the two LRU-most: eviction must skip them
        mgr.incref(chains[i])

    def evicted_chain():
        (i,) = [i for i, b in chains.items() if mgr.ref[b] == 0]
        del chains[i]
        return i

    freed = []
    for _ in range(6):
        assert tree.evict_one(mgr)
        freed.append(evicted_chain())
    assert freed == [0, 7, 2, 6, 1, 4], freed
    assert not tree.evict_one(mgr)  # only pinned leaves remain
    for i in (3, 5):
        mgr.decref(chains[i])  # unpin: candidates must resurface
    for expect in (3, 5):
        assert tree.evict_one(mgr)
        assert evicted_chain() == expect
    assert len(tree) == 0 and mgr.num_used == 0


def test_eviction_respects_dedup_touch_recency():
    """A dedup re-insert refreshes a chain's recency exactly like a
    match, so the untouched chain evicts first."""
    mgr = BlockManager(16)
    tree = RadixPrefixCache(BS)
    a = _insert_chain(tree, mgr, _prompt(1))
    b = _insert_chain(tree, mgr, _prompt(2))
    for blk in a + b:
        mgr.decref(blk)
    dup = mgr.alloc(1)  # second prefill of prompt 1: dedup touch
    tree.insert(_prompt(1), dup, mgr)
    mgr.decref(dup[0])
    assert tree.evict_one(mgr)
    assert mgr.ref[b[0]] == 0, "untouched chain should be LRU"
    assert mgr.ref[a[0]] == 1


def test_hit_stats_count_admissions_not_retries():
    """match() itself is stat-free (a queue-blocked request re-matches
    every admission attempt); record_lookup accounts the admitted
    result."""
    mgr = BlockManager(16)
    tree = RadixPrefixCache(BS)
    _insert_chain(tree, mgr, _prompt(1, 2))
    got = tree.match(_prompt(1, 2) + [0])
    got2 = tree.match(_prompt(1, 2) + [0])  # retry: no double count
    assert tree.hits == 0 and tree.misses == 0
    assert got == got2
    tree.record_lookup(len(got))  # the attempt that admitted
    tree.record_lookup(len(tree.match(_prompt(5) + [0])))
    assert tree.hits == 2 and tree.misses == 1
