"""Optimized-HLO text parsing for the roofline collective term.

Handles the cost_analysis blind spot: collectives inside ``while`` bodies
(lax.scan over layers / KV blocks / microbatches) are multiplied by the
loop's ``known_trip_count`` from XLA's backend_config, nested loops
compounding. Replica groups are expanded from the iota shorthand
(``[G,N]<=[dims]T(perm)``) so each collective gets:

  * its ring algorithm factor  (all-reduce 2(n-1)/n, gather/scatter (n-1)/n)
  * a pod-crossing flag (group spans devices of more than one pod) so
    inter-pod bytes can be priced at DCN bandwidth instead of ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_INSTR_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]"  # result dtype[dims] (first tuple elt)
)

_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(text: str):
    """Returns ({name: body_text}, entry_name)."""
    comps: Dict[str, str] = {}
    entry = None
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\([^)]*\))?.*\{\s*$", line)
        if m and not line.startswith(" "):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(2)
            if m.group(1):
                entry = cur_name
            cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps, entry


def _expand_groups(g: int, n: int, dims: str, perm: Optional[str]):
    shape = [int(d) for d in dims.split(",")]
    arr = np.arange(int(np.prod(shape))).reshape(shape)
    if perm:
        arr = arr.transpose([int(p) for p in perm.split(",")])
    return arr.reshape(g, n)


@dataclass
class Collective:
    kind: str
    bytes: int
    group_size: int
    crosses_pod: bool
    count: int = 1

    def alg_factor(self) -> float:
        n = max(self.group_size, 2)
        if self.kind == "all-reduce":
            return 2.0 * (n - 1) / n
        if self.kind in ("all-gather", "reduce-scatter"):
            return (n - 1) / n
        return 1.0


@dataclass
class ModuleCollectives:
    collectives: List[Collective] = field(default_factory=list)

    def weighted_ici_bytes(self) -> float:
        return sum(
            c.bytes * c.count * c.alg_factor()
            for c in self.collectives
            if not c.crosses_pod
        )

    def weighted_pod_bytes(self) -> float:
        return sum(
            c.bytes * c.count * c.alg_factor()
            for c in self.collectives
            if c.crosses_pod
        )

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + c.bytes * c.count
        return out

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + c.count
        return out


def cpu_upcast_correction(text: str, min_bytes: int = 50_000_000) -> int:
    """Bytes of CPU-only f32 copies of large bf16 tensors.

    XLA-CPU legalizes bf16 dots to f32: every bf16 weight/activation
    feeding a matmul gets an explicit ``f32 convert`` (and loop-invariant
    converts of scanned operands are hoisted out of while loops, pinning
    an f32 copy of the whole stacked buffer). None of this exists on TPU,
    whose MXU consumes bf16 natively. We sum the result sizes of large
    bf16→f32 converts, counting each distinct shape once (buffers of equal
    shape are reused by the allocator) — a documented *estimate* used to
    report a TPU-corrected temp figure next to the raw CPU number."""
    # name -> dtype for every defined value
    name_dt: Dict[str, str] = {}
    for m in re.finditer(r"%([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[", text):
        name_dt[m.group(1)] = m.group(2)
    seen: Dict[str, int] = {}
    for m in re.finditer(
        r"=\s*f32\[([0-9,]+)\][^=]*?\bconvert\(%([\w\.\-]+)\)", text
    ):
        dims, operand = m.groups()
        if name_dt.get(operand) != "bf16":
            continue
        b = _bytes_of("f32", dims)
        if b >= min_bytes:
            seen[dims] = b
    # while-state f32 stacks with a bf16 twin (hoisted stash converts)
    for m in re.finditer(r"while[\w\.]*\s*=\s*\(([^)]*)\)\s*while\(", text):
        tuple_txt = m.group(1)
        bf16_dims = {
            tm.group(1)
            for tm in re.finditer(r"bf16\[([0-9,]+)\]", tuple_txt)
        }
        for tm in re.finditer(r"f32\[([0-9,]+)\]", tuple_txt):
            dims = tm.group(1)
            if dims in bf16_dims:
                b = _bytes_of("f32", dims)
                if b >= min_bytes:
                    seen[dims] = b
    return sum(seen.values())


def parse_module_collectives(text: str,
                             pod_size: Optional[int] = None
                             ) -> ModuleCollectives:
    comps, entry = _split_computations(text)

    # while body -> trip count, and which computation contains the while
    body_trips: Dict[str, int] = {}
    contains: Dict[str, List[str]] = {}
    for name, body in comps.items():
        for line in body.splitlines():
            if "while(" not in line:
                continue
            m = _WHILE_RE.search(line)
            if not m:
                continue
            _cond, wbody = m.groups()
            tm = _TRIP_RE.search(line)
            trips = int(tm.group(1)) if tm else 1
            body_trips[wbody] = trips
            contains.setdefault(name, []).append(wbody)

    # multiplier per computation by DFS from entry (nested loops compound)
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for child in contains.get(name, []):
            visit(child, m * body_trips.get(child, 1))

    if entry:
        visit(entry, 1.0)

    out = ModuleCollectives()
    for name, body in comps.items():
        m = mult.get(name)
        if m is None:
            # Not reachable through tracked whiles from entry: count once if
            # it holds collectives (e.g. called computations we don't track).
            m = 1.0 if any(k in body for k in _COLL_KINDS) else 0.0
        if m == 0.0:
            continue
        for line in body.splitlines():
            kind = next(
                (
                    k
                    for k in _COLL_KINDS
                    if f" {k}(" in line or f"{k}-start(" in line
                ),
                None,
            )
            if kind is None:
                continue
            im = _INSTR_RE.search(line)
            if not im:
                continue
            nbytes = _bytes_of(im.group(1), im.group(2))
            gm = _GROUPS_RE.search(line)
            gsize, crosses = 2, False
            if gm:
                g, n, dims, perm = gm.groups()
                groups = _expand_groups(int(g), int(n), dims, perm)
                gsize = int(n)
                if pod_size:
                    crosses = bool(
                        ((groups // pod_size).max(axis=1)
                         != (groups // pod_size).min(axis=1)).any()
                    )
            out.collectives.append(
                Collective(kind, nbytes, gsize, crosses, count=int(m))
            )
    return out
