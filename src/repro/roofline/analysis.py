"""Roofline terms from compiled dry-run artifacts (TPU v5e target).

  compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory term     = HLO_bytes_per_device / HBM_bw_per_chip
  collective term = collective_bytes_per_device * alg_factor / ICI_bw

Per-device numbers: jax's ``compiled.cost_analysis()`` reports the SPMD
*per-device* program. CAVEAT measured empirically in this repo: XLA's cost
analysis counts a ``while`` (lax.scan) body ONCE, not × trip-count — so
scanned-layer models would be undercounted ~num_layers×. The dry-run
therefore reports two numbers per cell:

  * full-graph compile (proves shardability; memory_analysis is exact);
  * roofline terms assembled from a SINGLE-LAYER lowering × layer count
    (+ the full-graph's non-loop remainder), which is exact for uniform
    stacks and also ~100× cheaper to compile on this 1-core container.

Collective bytes are parsed from the optimized HLO text (operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute),
scaled by ring-algorithm factors, with while-loop bodies multiplied by
their statically-known trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link; v5e has 4 links but collectives serialize per ring

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# bytes-on-wire factor per collective kind (ring algorithms, large n)
_ALG_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

_WHILE_RE = re.compile(r"while\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def weighted_bytes(self) -> float:
        return sum(
            _ALG_FACTOR[k] * v for k, v in self.bytes_by_kind.items()
        )

    @property
    def raw_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str,
                      loop_trip_counts: Optional[Dict[str, int]] = None
                      ) -> CollectiveStats:
    """Sum collective payload bytes in an (optimized) HLO module text.

    HLO is printed with one computation per block; computations called
    from a while body appear once. `loop_trip_counts` maps computation
    names (e.g. "while_body") to multipliers; by default, computations
    whose name contains 'body' of a while with known trip count get
    multiplied — we detect trip counts from the canonical
    `trip_count=<N>` comments XLA emits when known, else 1."""
    stats = CollectiveStats()
    # split into computations
    comps = re.split(r"\n(?=[%\w\.\-]+\s*\{|ENTRY)", hlo_text)
    # detect known trip counts: XLA prints e.g. `// trip count: 80` rarely;
    # jax scans lower with a constant upper bound visible as
    # `s32[] constant(N)` compared in the cond — too fragile, so callers
    # pass explicit counts; default 1.
    for comp in comps:
        header = comp.split("{", 1)[0]
        mult = 1
        if loop_trip_counts:
            for key, count in loop_trip_counts.items():
                if key in header:
                    mult = count
                    break
        for m in _COLL_RE.finditer(comp):
            dtype, dims, kind, _ = m.groups()
            b = _shape_bytes(dtype, dims) * mult
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
            stats.count_by_kind[kind] = (
                stats.count_by_kind.get(kind, 0) + mult
            )
    return stats


@dataclass
class RooflineReport:
    name: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_weighted: float
    model_flops_total: float  # 6·N·D (or 6·N_active·D)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_weighted / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs across chips — catches remat and
        redundancy waste (>1/3 is typical with full remat: fwd+bwd+rematfwd)."""
        total = self.flops_per_device * self.chips
        return self.model_flops_total / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline realized if the program ran at
        the bound: t_compute / max(all terms)."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_weighted": self.collective_bytes_weighted,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def serving_program_bounds(cfg, batch: int, prefill_chunk: int,
                           verify_lanes: int = 1,
                           dtype_bytes: int = 2) -> Dict[str, float]:
    """Predicted roofline lower bound (seconds) for ONE invocation of each
    serving program (serve/programs.py) on the TPU v5e target:

        t_bound = max(2·N_active·tokens / PEAK_FLOPS, N_active·B / HBM_BW)

    tokens per call: ``batch`` for decode (one token per row),
    ``prefill_chunk`` for a chunked-prefill call (batch-1),
    ``batch·verify_lanes`` for a speculative verify. The memory term is
    the weight stream (active params read once per call) — the dominant
    decode traffic; KV reads are excluded, so the bound is optimistic and
    the efficiency ratio ``t_bound / measured`` stays in (0, 1] on the
    target (and is simply an attribution number on other hosts).
    ``ServeEngine.program_efficiency()`` joins these with the
    ``ProgramTimer`` measured wall times."""
    n_active = cfg.active_param_count()
    w_bytes = n_active * dtype_bytes

    def bound(tokens: int) -> float:
        return max(2.0 * n_active * tokens / PEAK_FLOPS_BF16,
                   w_bytes / HBM_BW)

    return {
        "decode": bound(batch),
        "prefill_chunk": bound(prefill_chunk),
        "verify": bound(batch * verify_lanes),
    }


def model_flops(cfg, shape, mode: str) -> float:
    """6·N·D for training; 2·N·D for one forward (prefill); 2·N_active per
    decoded token. N = active params (MoE-aware)."""
    n_active = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
