"""Analytic FLOP / HBM-byte model per (arch × shape) — the napkin math the
roofline is anchored on.

Why analytic: XLA's cost_analysis counts scan bodies once (verified in this
repo), so scanned-layer training graphs under-report ~num_layers×. The
models below count matmul FLOPs per layer from the config (exact for the
dominant terms; elementwise ignored), are cross-checked against HLO
cost_analysis on the *unrolled* decode graphs (where cost_analysis is
trustworthy — see tests/test_roofline.py), and scale with documented
assumptions:

  * train FLOPs = fwd × (1 + 2 [bwd] + 1 [full remat recompute]).
  * HBM bytes = param traffic (bf16 reads × passes + fp32 optimizer r/w)
    + layer-boundary activation traffic + attention KV/cache traffic +
    logits. Perfect sharding assumed (global / chips).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs import SHAPES


def _attn_flops(cfg, t: int, ctx: float, is_global: bool) -> float:
    a = cfg.attention
    d = cfg.d_model
    if a.kind == "mla":
        dn, dr, dv, r, h = (
            a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim,
            a.kv_lora_rank, a.num_heads,
        )
        f = 2 * t * d * h * (dn + dr)  # q proj
        f += 2 * t * d * r + 2 * t * d * dr  # kv down + krope
        f += 2 * t * h * dn * r  # q absorb
        f += 2 * t * h * ctx * (r + dr)  # scores (latent)
        f += 2 * t * h * ctx * r  # weighted latent
        f += 2 * t * h * r * dv  # uv expand
        f += 2 * t * h * dv * d  # out proj
        return f
    h, g, hd = a.num_heads, a.num_kv_heads, a.head_dim
    if a.sliding_window is not None and not is_global:
        ctx = min(ctx, a.sliding_window)
    f = 2 * t * d * h * hd  # q
    f += 2 * 2 * t * d * g * hd  # k, v
    f += 2 * t * h * hd * d  # o
    f += 2 * 2 * t * h * hd * ctx  # qk + av
    return f


def _ssm_flops(cfg, t: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    gn = s.ngroups * s.state_dim
    f = 2 * t * d * (2 * di + 2 * gn + nh)  # z,x,B,C,dt projections
    f += 2 * t * (di + 2 * gn) * s.conv_width  # causal conv
    L, n, dh = s.chunk_size, s.state_dim, s.head_dim
    # SSD: intra-chunk scores + mix, chunk states, inter-chunk outputs
    f += t * nh * (2 * L * n + 2 * L * dh + 4 * n * dh)
    f += 2 * t * di * d  # out proj
    return f


def _mlp_flops(cfg, t: int, d_ff: int) -> float:
    return 6 * t * cfg.d_model * d_ff


def _moe_flops(cfg, b: int, s: int) -> float:
    m = cfg.moe
    d = cfg.d_model
    ffe = m.expert_d_ff or cfg.d_ff
    t = b * s
    if m.variant == "soft" or m.variant in (
        "identity", "uniform", "soft_uniform", "uniform_soft"
    ):
        ns = m.total_slots()
        f = 6 * t * d * ns  # logits + dispatch mix + combine mix
        f += b * ns * 6 * d * ffe  # experts on slots
    else:
        f = 2 * t * d * m.num_experts  # router
        f += 6 * t * m.top_k * d * ffe  # routed experts
    f += 6 * t * d * ffe * m.num_shared_experts
    return f


def fwd_flops(cfg, batch: int, seq: int, mode: str,
              cache_len: int = 0) -> float:
    """One forward pass, global (all chips)."""
    t = batch * seq
    if mode == "train" or mode == "prefill":
        ctx = seq / 2 if cfg.causal else seq  # causal average
    else:
        ctx = cache_len
    moe_idx = set(cfg.moe_layer_indices())
    total = 0.0
    for i in range(cfg.num_layers):
        is_global = (
            cfg.attention.is_global_layer(i) if cfg.attention else True
        )
        if cfg.has_attention():
            total += _attn_flops(cfg, t, ctx, is_global)
        if cfg.has_ssm():
            total += _ssm_flops(cfg, t)
        if cfg.moe is not None and i in moe_idx:
            total += _moe_flops(cfg, batch, seq)
        elif cfg.d_ff > 0:
            total += _mlp_flops(cfg, t, cfg.d_ff)
    if cfg.encoder_layers:
        te = batch * cfg.frontend.num_embeds
        if mode != "decode":
            # encoder runs once (at train/prefill); decode reuses enc_out
            for i in range(cfg.encoder_layers):
                total += _attn_flops(cfg, te, cfg.frontend.num_embeds, True)
                if cfg.d_ff > 0:
                    total += _mlp_flops(cfg, te, cfg.d_ff)
        # cross attention in every decoder layer (kv cached at decode)
        a = cfg.attention
        kv_flops = 2 * 2 * te * cfg.d_model * a.num_kv_heads * a.head_dim
        total += cfg.num_layers * (
            2 * t * cfg.d_model * a.num_heads * a.head_dim * 2  # q,o
            + (0 if mode == "decode" else kv_flops)
            + 2 * 2 * t * a.num_heads * a.head_dim * cfg.frontend.num_embeds
        )
    if cfg.frontend.kind != "none":
        total += 2 * batch * cfg.frontend.num_embeds * (
            cfg.frontend.embed_dim * cfg.d_model
        )
    if cfg.vocab_size:
        # prefill/decode unembed only the final position per sequence
        t_un = t if mode == "train" else batch
        total += 2 * t_un * cfg.d_model * cfg.vocab_size
    return total


@dataclass
class AnalyticCost:
    flops_global: float
    bytes_global: float
    notes: str = ""

    def per_device(self, chips: int):
        return self.flops_global / chips, self.bytes_global / chips


def _param_bytes(cfg) -> float:
    return float(cfg.param_count())


def _cache_bytes(cfg, batch: int, length: int) -> float:
    a = cfg.attention
    total = 0.0
    for i in range(cfg.num_layers):
        if a is not None:
            ln = length
            if a.sliding_window is not None and not a.is_global_layer(i):
                ln = min(length, a.sliding_window)
            if a.kind == "mla":
                total += batch * ln * (a.kv_lora_rank + a.qk_rope_head_dim) * 2
            else:
                total += batch * ln * 2 * a.num_kv_heads * a.head_dim * 2
        if cfg.ssm is not None:
            s = cfg.ssm
            total += batch * s.num_heads(cfg.d_model) * s.head_dim * \
                s.state_dim * 4
    return total


def analytic_cost(cfg, shape_name: str) -> AnalyticCost:
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    p = _param_bytes(cfg)
    d = cfg.d_model
    if shape.mode == "train":
        f_fwd = fwd_flops(cfg, b, s, "train")
        remat = 1.0 if cfg.remat else 0.0
        flops = f_fwd * (3.0 + remat)
        t = b * s
        bytes_ = (
            p * 2 * 3  # bf16 param reads: fwd + bwd + remat
            + p * 4 * 2 * 3  # fp32 master+moments read/write in optimizer
            + cfg.num_layers * t * d * 2 * 6  # layer-boundary activations
            + t * cfg.vocab_size * 4 * 2  # logits write+read (loss)
        )
        return AnalyticCost(flops, bytes_, "train: fwd+bwd+remat")
    if shape.mode == "prefill":
        flops = fwd_flops(cfg, b, s, "prefill")
        t = b * s
        bytes_ = p * 2 + cfg.num_layers * t * d * 2 * 2 + _cache_bytes(
            cfg, b, s
        )
        return AnalyticCost(flops, bytes_, "prefill: 1 fwd + cache write")
    # decode: one token, full cache read
    flops = fwd_flops(cfg, b, 1, "decode", cache_len=s)
    bytes_ = p * 2 + _cache_bytes(cfg, b, s) + b * cfg.vocab_size * 4
    return AnalyticCost(flops, bytes_, "decode: params + cache read / token")
