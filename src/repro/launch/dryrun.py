import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax import —
# jax locks the device count at first init)
"""Multi-pod dry-run: lower + compile EVERY (architecture × input shape)
cell on the production meshes, print memory/cost analysis, and emit the
roofline terms (compute / memory / collective) per cell.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      [--multi-pod] [--fsdp/--no-fsdp] [--out results.jsonl]
  python -m repro.launch.dryrun --all [--multi-pod]   # every valid cell

One CPU core compiles these; cells are independent so the driver writes
one JSON line per cell and can resume (--skip-done).
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ASSIGNED_NAMES, SHAPES, get_config, shape_supported
from ..distributed.api import use_mesh
from ..distributed.compat import cost_analysis_dict
from ..distributed.sharding import ShardingOptions
from ..roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    model_flops,
)
from ..roofline.flops import analytic_cost
from ..roofline.hlo_parse import cpu_upcast_correction, parse_module_collectives
from .mesh import make_production_mesh
from .specs import build_cell

DCN_BW = 25e9  # inter-pod (data-center network) bytes/s per chip, effective


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             opts: ShardingOptions | None = None, microbatches: int = 1,
             use_kernel: bool = False, dp_over_model: bool = False,
             zero1: bool = False, cfg_overrides: dict | None = None,
             verbose: bool = True) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, reason = shape_supported(cfg, shape_name)
    if not ok:
        return {"cell": f"{arch}:{shape_name}", "status": "skipped",
                "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with use_mesh(mesh, dp_over_model=dp_over_model):
        cell = build_cell(cfg, shape_name, mesh, opts,
                          microbatches=microbatches, use_kernel=use_kernel,
                          zero1=zero1)
        jitted = jax.jit(
            cell.fn, in_shardings=cell.in_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    hlo_text = compiled.as_text()
    mc = parse_module_collectives(
        hlo_text, pod_size=256 if multi_pod else None
    )
    # clamp: shape-keyed estimate can exceed the true peak (buffer reuse)
    upcast = min(cpu_upcast_correction(hlo_text), mem.temp_size_in_bytes)

    shape = SHAPES[shape_name]
    an = analytic_cost(cfg, shape_name)
    flops_dev, bytes_dev = an.per_device(chips)
    coll_ici = mc.weighted_ici_bytes()
    coll_pod = mc.weighted_pod_bytes()

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_ici / ICI_BW + coll_pod / DCN_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, shape.mode)

    result = {
        "cell": f"{arch}:{shape_name}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "args_bytes_per_dev": mem.argument_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            # XLA-CPU computes bf16 dots in f32 and hoists stacked-operand
            # converts out of loops; these f32 copies would not exist on
            # TPU (native bf16 MXU). See hlo_parse.cpu_upcast_correction.
            "cpu_f32_upcast_bytes": upcast,
            "tpu_corrected_temp_bytes": mem.temp_size_in_bytes - upcast,
            "output_bytes_per_dev": mem.output_size_in_bytes,
        },
        "hlo_cost": {
            "flops_per_dev": ca.get("flops", 0.0),
            "bytes_per_dev": ca.get("bytes accessed", 0.0),
            "note": "scan bodies counted once by XLA (see roofline docs)",
        },
        "analytic": {
            "flops_per_dev": flops_dev,
            "bytes_per_dev": bytes_dev,
            "note": an.notes,
        },
        "collectives": {
            "by_kind_bytes": mc.by_kind(),
            "counts": mc.counts(),
            "ici_weighted_bytes": coll_ici,
            "pod_weighted_bytes": coll_pod,
        },
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "bottleneck": bottleneck,
            "bound_s": max(terms.values()),
            "roofline_fraction": (
                t_compute / max(terms.values()) if max(terms.values()) else 0
            ),
            "model_flops": mf,
            "useful_flops_fraction": (
                mf / (flops_dev * chips) if flops_dev else 0
            ),
        },
    }
    if verbose:
        print(json.dumps(result))
    return result


def all_cells():
    for arch in ASSIGNED_NAMES:
        for shape in SHAPES:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--dp-over-model", action="store_true",
                    help="pure data parallelism: batch over model axis too")
    ap.add_argument("--no-tp", action="store_true",
                    help="disable tensor/expert parallelism")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: bf16 compute params replicated over data")
    ap.add_argument("--tag", default=None, help="label for perf iterations")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    opts = ShardingOptions(
        fsdp=not args.no_fsdp,
        tensor_parallel=not args.no_tp,
        expert_parallel=not args.no_tp,
    )
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r.get("cell"), r.get("mesh", mesh_name)))
                except json.JSONDecodeError:
                    pass

    cells = (
        list(all_cells()) if args.all else [(args.arch, args.shape)]
    )
    results = []
    for arch, shape in cells:
        if (f"{arch}:{shape}", mesh_name) in done:
            print(f"# skip (done): {arch}:{shape}")
            continue
        try:
            r = run_cell(
                arch, shape, multi_pod=args.multi_pod, opts=opts,
                microbatches=args.microbatches, use_kernel=args.use_kernel,
                dp_over_model=args.dp_over_model, zero1=args.zero1,
            )
        except Exception as e:  # a cell failure is a bug — record it
            traceback.print_exc()
            r = {"cell": f"{arch}:{shape}", "mesh": mesh_name,
                 "status": "error", "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(r))
        if args.tag:
            r["tag"] = args.tag
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"# dry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
