"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell —
weak-type-correct, shardable, zero device allocation — plus the sharding
pytrees the dry-run jits against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ShapeConfig
from ..distributed.sharding import ShardingOptions, tree_shardings
from ..models import build_model, init_cache
from ..models.encdec import init_encdec_cache
from ..optim import OptimizerConfig
from ..serve.engine import make_decode_step, make_prefill_step
from ..train.step import init_train_state, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _batch_axes(mesh: Mesh):
    from ..distributed.api import batch_over_model

    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if batch_over_model():
        ba = ba + ("model",)
    return ba


def _batch_size_ok(mesh: Mesh, b: int) -> int:
    n = 1
    for a in _batch_axes(mesh):
        n *= mesh.shape[a]
    return b % n == 0


def batch_specs(cfg, shape: ShapeConfig, mesh: Mesh) -> Dict[str, Any]:
    """Training batch ShapeDtypeStructs for one arch."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend.kind == "vision" and cfg.family == "vlm":
        # frontend stub embeds occupy part of the sequence budget
        n = cfg.frontend.num_embeds
        out["tokens"] = _sds((b, s - n), jnp.int32)
        out["embeds"] = _sds((b, n, cfg.frontend.embed_dim), jnp.bfloat16)
    elif cfg.encoder_layers > 0:
        out["tokens"] = _sds((b, s), jnp.int32)
        out["embeds"] = _sds(
            (b, cfg.frontend.num_embeds, cfg.frontend.embed_dim), jnp.bfloat16
        )
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
    return out


def _ba_for(mesh: Mesh, dim: int):
    """Batch axes, dropped when the batch dim is not divisible (e.g. the
    long_500k shape has global_batch=1: replicate instead)."""
    ba = _batch_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    return ba if dim % n == 0 else None


def batch_shardings(mesh: Mesh, batch):
    def one(leaf):
        return NamedSharding(
            mesh, P(_ba_for(mesh, leaf.shape[0]), *(None,) * (leaf.ndim - 1))
        )

    return jax.tree_util.tree_map(one, batch)


# ---------------------------------------------------------------------------
# cache shardings (serving cells)
# ---------------------------------------------------------------------------


def cache_shardings(mesh: Mesh, cache, cfg):
    """KV caches: batch over (pod,data); kv-heads over model when divisible,
    else the sequence dim over model (context parallelism — the 72B decode
    cache at 32k × 128 batch does not fit per-chip otherwise)."""
    model_size = mesh.shape["model"]

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "pos":
            return NamedSharding(mesh, P())
        ba = _ba_for(mesh, leaf.shape[0])
        if leaf.ndim == 4 and name in ("k", "v"):
            b, s, g, hd = leaf.shape
            if g % model_size == 0:
                return NamedSharding(mesh, P(ba, None, "model", None))
            if s % model_size == 0:
                return NamedSharding(mesh, P(ba, "model", None, None))
            return NamedSharding(mesh, P(ba, None, None, None))
        if name in ("ckv", "krope"):  # (b, s, r)
            b, s, r = leaf.shape
            if s % model_size == 0:
                return NamedSharding(mesh, P(ba, "model", None))
            return NamedSharding(mesh, P(ba, None, None))
        if name == "state":  # ssm (b, h, dh, n)
            h = leaf.shape[1]
            if h % model_size == 0:
                return NamedSharding(mesh, P(ba, "model", None, None))
            return NamedSharding(mesh, P(ba, None, None, None))
        if name == "conv":  # (b, w-1, C)
            return NamedSharding(mesh, P(ba, None, None))
        return NamedSharding(mesh, P(ba, *(None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    """Everything the dry-run needs to lower one (arch × shape × mesh)."""

    name: str
    fn: Any
    args: tuple
    in_shardings: tuple
    donate: tuple = ()


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _bf16_params(params_abs):
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return x

    return jax.tree_util.tree_map(cast, params_abs)


def build_cell(cfg, shape_name: str, mesh: Mesh,
               opts: ShardingOptions | None = None,
               microbatches: int = 1,
               use_kernel: bool = False,
               zero1: bool = False) -> Cell:
    shape = SHAPES[shape_name]
    opts = opts or ShardingOptions()
    init_fn, loss_fn, _ = build_model(cfg)
    rng = jax.random.PRNGKey(0)

    if shape.mode == "train":
        state_abs = _abstract(
            lambda r: init_train_state(r, init_fn, zero1=zero1), rng
        )
        batch_abs = batch_specs(cfg, shape, mesh)
        from ..train.step import state_shardings as st_sh

        state_sh = st_sh(mesh, state_abs, opts)
        step = make_train_step(
            lambda p, b: loss_fn(p, b), OptimizerConfig(),
            microbatches=microbatches,
        )
        return Cell(
            name=f"{cfg.name}:{shape_name}",
            fn=step,
            args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_shardings(mesh, batch_abs)),
            donate=(0,),
        )

    # serving cells: bf16 params, no optimizer state. FSDP is a training
    # layout — at decode it would all-gather the full weights EVERY token
    # (measured 17.5GB/step on qwen2-72b:decode_32k → roofline fraction
    # 0.002); inference shards over `model` only and replicates over data.
    opts = dataclasses.replace(opts, fsdp=False)
    params_abs = _bf16_params(_abstract(init_fn, rng))
    params_sh = tree_shardings(mesh, params_abs, opts)
    b, s = shape.global_batch, shape.seq_len

    if cfg.encoder_layers > 0:
        from ..models.encdec import decode_step as ed_decode

        cache_abs = _abstract(
            lambda: init_encdec_cache(cfg, b, s)
        )
        enc_out_abs = _sds((b, cfg.frontend.num_embeds, cfg.d_model),
                           jnp.bfloat16)
        ba = _batch_axes(mesh)
        enc_sh = NamedSharding(mesh, P(ba, None, None))
        if shape.mode == "prefill":
            # prefill = encode(frames) + decoder prefill, one step
            from ..models.encdec import encdec_apply

            toks = _sds((b, s), jnp.int32)
            frames_abs = _sds(
                (b, cfg.frontend.num_embeds, cfg.frontend.embed_dim),
                jnp.bfloat16,
            )

            def fn(params, tokens, frames, cache):
                import jax.numpy as jnp_

                enc_out, enc_aux = __import__("repro.models.encdec",
                                              fromlist=["encode"]).encode(
                    params, cfg, frames)
                logits, cache, _ = ed_decode(
                    params, cfg, tokens, enc_out,
                    positions=jnp_.arange(s), cache=cache, mode="prefill",
                    last_only=True,
                )
                return logits, enc_out, cache

            return Cell(
                name=f"{cfg.name}:{shape_name}", fn=fn,
                args=(params_abs, toks, frames_abs, cache_abs),
                in_shardings=(
                    params_sh, batch_shardings(mesh, toks),
                    batch_shardings(mesh, frames_abs),
                    _encdec_cache_sh(mesh, cache_abs, cfg),
                ),
                donate=(3,),
            )
        toks = _sds((b, 1), jnp.int32)
        pos = _sds((), jnp.int32)

        def fn(params, tokens, pos, enc_out, cache):
            import jax.numpy as jnp_

            logits, cache, _ = ed_decode(
                params, cfg, tokens, enc_out,
                positions=pos[None], cache=cache, mode="decode",
            )
            return logits, cache

        return Cell(
            name=f"{cfg.name}:{shape_name}", fn=fn,
            args=(params_abs, toks, pos, enc_out_abs, cache_abs),
            in_shardings=(
                params_sh, batch_shardings(mesh, toks),
                NamedSharding(mesh, P()), enc_sh,
                _encdec_cache_sh(mesh, cache_abs, cfg),
            ),
            donate=(4,),
        )

    cache_abs = _abstract(lambda: init_cache(cfg, b, s))
    cache_sh = cache_shardings(mesh, cache_abs, cfg)
    if shape.mode == "prefill":
        toks = _sds((b, s), jnp.int32)
        fn = make_prefill_step(cfg, s)
        return Cell(
            name=f"{cfg.name}:{shape_name}", fn=fn,
            args=(params_abs, toks, cache_abs),
            in_shardings=(params_sh, batch_shardings(mesh, toks), cache_sh),
            donate=(2,),
        )
    # decode: per-row positions (continuous batching — rows at independent
    # offsets; pos<0 rows are inactive no-ops)
    toks = _sds((b, 1), jnp.int32)
    pos = _sds((b,), jnp.int32)
    fn = make_decode_step(cfg)
    return Cell(
        name=f"{cfg.name}:{shape_name}", fn=fn,
        args=(params_abs, toks, pos, cache_abs),
        in_shardings=(
            params_sh, batch_shardings(mesh, toks),
            NamedSharding(mesh, P()), cache_sh,
        ),
        donate=(3,),
    )


def _encdec_cache_sh(mesh, cache_abs, cfg):
    return cache_shardings(mesh, cache_abs, cfg)
