import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
"""Perf hillclimb driver (§Perf methodology): run a named list of
(cell × sharding-variant) combinations, appending tagged results to
results/perf_iterations.jsonl. Each variant encodes one hypothesis from
EXPERIMENTS.md §Perf; the roofline deltas are the measurements.
"""
import json
import sys
import traceback

from ..distributed.sharding import ShardingOptions
from .dryrun import run_cell

# (tag, arch, shape, kwargs)
VARIANTS = [
    # --- cell 1: mamba2-370m:train_4k (worst roofline fraction 0.046) ----
    # H1: 370M params can't feed a 16-wide TP axis; make the model axis
    # extra data parallelism (pure DP) — grad all-reduce ~3GB vs compute
    # 72ms => compute-bound.
    ("mamba2_base", "mamba2-370m", "train_4k", {}),
    ("mamba2_pure_dp", "mamba2-370m", "train_4k",
     {"opts": ShardingOptions(tensor_parallel=False, expert_parallel=False),
      "dp_over_model": True}),
    # H2: + ZeRO-1 (grads reduce-scatter + one param gather, no per-layer
    # FSDP gathers)
    ("mamba2_pure_dp_zero1", "mamba2-370m", "train_4k",
     {"opts": ShardingOptions(tensor_parallel=False, expert_parallel=False),
      "dp_over_model": True, "zero1": True}),
    # --- cell 2: qwen2-72b:train_4k (flagship; collective-bound 0.685) ---
    # H3: FSDP re-gathers every layer every pass (~914GB/step); ZeRO-1
    # replaces that with one grad RS + one param AG per step.
    ("qwen72b_base", "qwen2-72b", "train_4k", {}),
    ("qwen72b_zero1", "qwen2-72b", "train_4k", {"zero1": True}),
    # H4: microbatching with ZeRO-1 (activation collectives shrink per
    # microbatch; params gathered once regardless).
    ("qwen72b_zero1_mb4", "qwen2-72b", "train_4k",
     {"zero1": True, "microbatches": 4}),
    # --- cell 3: deepseek-v2-lite:train_4k (0.019; paper-representative
    # MoE routing) -----------------------------------------------------
    # H5: scatter/gather token routing under expert-parallelism makes
    # GSPMD all-gather the routed buffers per layer per pass (~791GB).
    # With experts replicated (EP off; FSDP shards their 1.1GB/layer),
    # routing is device-local.
    ("deepseek_base", "deepseek-v2-lite-16b", "train_4k", {}),
    ("deepseek_no_ep", "deepseek-v2-lite-16b", "train_4k",
     {"opts": ShardingOptions(expert_parallel=False)}),
    ("deepseek_no_ep_zero1", "deepseek-v2-lite-16b", "train_4k",
     {"opts": ShardingOptions(expert_parallel=False), "zero1": True}),
    # H6: the paper's router at the same station — Soft MoE has no
    # scatter/top-k at all; dispatch/combine are dense einsums that
    # shard cleanly (slots over model).
    ("deepseek_soft_base", "deepseek-v2-lite-16b+soft", "train_4k", {}),
    # H7: pin the Soft-MoE weight/slot tensors slot-replicated (gather
    # the small axis) instead of GSPMD's output all-reduce; see
    # core/soft_moe.py distribution note. Runs with EP on.
    ("deepseek_soft_slotrep", "deepseek-v2-lite-16b+soft", "train_4k", {}),
    ("deepseek_soft_slotrep_zero1", "deepseek-v2-lite-16b+soft", "train_4k",
     {"zero1": True}),
    ("deepseek_soft_no_ep_zero1", "deepseek-v2-lite-16b+soft", "train_4k",
     {"opts": ShardingOptions(expert_parallel=False), "zero1": True}),
    # qwen2-0.5b (prefill was 0.505; train 0.263): pure DP like mamba2
    ("qwen05b_pure_dp_zero1", "qwen2-0.5b", "train_4k",
     {"opts": ShardingOptions(tensor_parallel=False, expert_parallel=False),
      "dp_over_model": True, "zero1": True}),
]


def main():
    names = sys.argv[1:] or [v[0] for v in VARIANTS]
    out = "results/perf_iterations.jsonl"
    done = set()
    if os.path.exists(out):
        for line in open(out):
            try:
                r = json.loads(line)
                if r.get("status") == "ok":
                    done.add(r.get("tag"))
            except json.JSONDecodeError:
                pass
    for tag, arch, shape, kw in VARIANTS:
        if tag not in names or tag in done:
            continue
        print(f"### {tag}")
        try:
            r = run_cell(arch, shape, **kw)
        except Exception as e:
            traceback.print_exc()
            r = {"cell": f"{arch}:{shape}", "status": "error",
                 "error": f"{type(e).__name__}: {e}"}
        r["tag"] = tag
        with open(out, "a") as f:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
