"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only data parallelism (gradient all-reduce), matching the slower inter-pod
links.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """Smallest valid mesh on whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
