"""Soft MoE — the paper's contribution (Puigcerver et al., ICLR 2024, §2).

Faithful to Algorithm 1 + the Algorithm 2 L2-normalization fix:

    logits = l2norm(X) @ (scale * l2norm(Phi))        # (m, n·p)
    D = softmax over tokens  (per slot / column)       # dispatch
    C = softmax over slots   (per token / row)         # combine
    X~ = Dᵀ X ; Y~_i = f_{⌊i/p⌋}(X~_i) ; Y = C Y~

Every op is continuous/differentiable; there is no top-k/sort anywhere on
this path (the paper's perf point). Experts are stacked along a leading
axis so they shard over the `model` mesh axis (expert parallelism); Phi is
sharded over its slot axis the same way.

``use_kernel=True`` routes dispatch/combine through the fused Pallas TPU
kernels in ``repro.kernels`` (interpret-mode on CPU).

Per-sequence invariant: both softmaxes normalize WITHIN one sequence —
dispatch over that sequence's m tokens (axis 1), combine over its n·p
slots — and the expert mixes are per-row weighted sums, so a sequence's
output is identical however it is batched (the paper's §3.5 contrast
with sparse routing, and the reason Soft MoE is batch-invariant at
serving with no mode switch; the fused kernels keep the batch axis a
pure grid axis — see kernels/soft_moe_kernels.py — and ref.py states the
same math for a single sequence). Unlike the sparse variants there is no
train/serve routing split to thread ``mode`` through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.api import constrain
from ..layers.common import l2_normalize, lecun_init, split_rngs
from ..layers.mlp import expert_init, experts_apply


def soft_moe_init(rng, d_model: int, moe_cfg, style: str = "gated"):
    r_phi, r_e = split_rngs(rng, 2)
    n, p = moe_cfg.num_experts, moe_cfg.slots_per_expert
    d_ff = moe_cfg.expert_d_ff
    params = {
        "phi": lecun_init(r_phi, (d_model, n, p), fan_in=d_model),
        "scale": jnp.ones(()),
        "experts": expert_init(r_e, n, d_model, d_ff, style),
    }
    if moe_cfg.num_shared_experts:
        params["shared"] = expert_init(
            jax.random.fold_in(r_e, 1), moe_cfg.num_shared_experts, d_model,
            d_ff, style,
        )
    return params


def soft_moe_weights(x, phi, scale, normalize: bool = True):
    """Dispatch/combine weights for one sequence batch.

    x: (b, m, d); phi: (d, n, p). Returns (d_weights, c_weights), both
    (b, m, n, p): D normalized over m, C normalized over (n, p).
    """
    if normalize:
        x = l2_normalize(x, axis=-1)
        phi = scale * l2_normalize(phi, axis=0)
    logits = jnp.einsum(
        "bmd,dnp->bmnp", x.astype(jnp.float32), phi.astype(jnp.float32)
    )
    d_weights = jax.nn.softmax(logits, axis=1)  # over tokens (per slot)
    b, m, n, p = logits.shape
    c_weights = jax.nn.softmax(
        logits.reshape(b, m, n * p), axis=-1
    ).reshape(b, m, n, p)  # over all slots (per token)
    return d_weights, c_weights


def soft_moe_apply(params, moe_cfg, x, act: str = "silu",
                   use_kernel: bool = False, telemetry: bool = False):
    """x: (b, m, d) -> (b, m, d). Returns (y, metrics).

    ``telemetry=True`` adds a ``metrics["telemetry"]`` dict of
    ``stop_gradient``'d f32 scalars — the Fig. 9 routing-health set (see
    docs/observability.md). It never changes ``y``: the kernel path reads
    the routing pass's saved softmax stats (plus one extra logits pass in
    ``routing_health``) instead of materializing the (m × S) weights.
    """
    b, m, d = x.shape
    n, p = moe_cfg.num_experts, moe_cfg.slots_per_expert
    phi = params["phi"]
    c_weights = c_stats = d_w = d_stats = None
    if use_kernel:
        from ..kernels import ops as kops
        from ..kernels.tuning import config_from_moe

        kcfg = config_from_moe(moe_cfg, m=m, d=d)
        phi_n = kops.normalized_phi(phi, params["scale"])
        # one logits pass: dispatched slots + the combine softmax stats
        if telemetry:
            slots, d_stats, c_stats = kops.soft_moe_routing(
                x, phi_n, config=kcfg, with_d_stats=True)
        else:
            slots, c_stats = kops.soft_moe_routing(x, phi_n, config=kcfg)
        slots = slots.reshape(b, n, p, d)  # (b, n·p, d) -> (b, n, p, d)
    else:
        d_w, c_weights = soft_moe_weights(x, phi, params["scale"])
        # Distribution note: GSPMD's propagated layout (slot axis of the
        # weight tensors sharded with Phi over `model`) is left alone.
        # Forcing slot-replication here (gather the small axis early,
        # avoid the combine all-reduce) was tried and REFUTED — it ADDED
        # ~1.3s/step of resharding traffic at deepseek+soft:train_4k
        # (EXPERIMENTS.md §Perf, H7).
        # input slots: weighted average of all tokens per slot
        slots = jnp.einsum("bmd,bmnp->bnpd", x.astype(jnp.float32), d_w)
    slots = slots.astype(x.dtype)

    # expert compute: (b,n,p,d) -> (n, b*p, d) so the expert axis leads
    # (sharded over `model` = expert parallelism)
    ys = slots.transpose(1, 0, 2, 3).reshape(n, b * p, d)
    ys = experts_apply(params["experts"], ys, act)
    ys = ys.reshape(n, b, p, d).transpose(1, 0, 2, 3)  # (b,n,p,d)

    if use_kernel:
        y = kops.soft_moe_combine(x, phi_n, ys.reshape(b, n * p, d),
                                  c_stats=c_stats, config=kcfg)
    else:
        y = jnp.einsum(
            "bnpd,bmnp->bmd", ys.astype(jnp.float32), c_weights
        )
    y = y.astype(x.dtype)

    if moe_cfg.num_shared_experts:
        # reshape once; experts_apply broadcasts the leading expert axis
        # (no (num_shared × b·m × d) materialization).
        sh = experts_apply(params["shared"], x.reshape(1, b * m, d), act)
        y = y + sh.sum(0).reshape(b, m, d)

    metrics = {
        "moe_aux_loss": jnp.zeros((), jnp.float32),  # balanced by construction
    }
    # model-inspection stat (paper §5 / App. E): max combine weight —
    # values approaching 1.0 signal the softmax collapse the L2-norm fix
    # prevents. On the kernel path it falls out of the saved softmax
    # stats: the max weight for token i is exp(mx_i − mx_i)/den_i = 1/den_i.
    if c_weights is not None:
        metrics["max_combine"] = jax.lax.stop_gradient(c_weights.max())
    elif c_stats is not None:
        metrics["max_combine"] = jax.lax.stop_gradient(
            (1.0 / c_stats[1]).max()
        )
    if telemetry:
        if use_kernel:
            sg = jax.lax.stop_gradient
            dent, imp, cent, contrib = kops.routing_health(
                sg(x), sg(phi_n), jax.tree_util.tree_map(sg, d_stats),
                jax.tree_util.tree_map(sg, c_stats), config=kcfg)
            max_dispatch = (1.0 / d_stats[1]).max()
        else:
            dent, imp, cent, contrib = _dense_routing_health(d_w, c_weights)
            max_dispatch = d_w.max()
        imp_e = imp.reshape(b, n, p).sum(axis=(0, 2))  # per-expert mass
        metrics["telemetry"] = jax.tree_util.tree_map(
            jax.lax.stop_gradient,
            {
                "max_combine": metrics["max_combine"],
                "max_dispatch": max_dispatch.astype(jnp.float32),
                "dispatch_entropy": dent.mean().astype(jnp.float32),
                "combine_entropy": cent.mean().astype(jnp.float32),
                "expert_importance_spread": (
                    imp_e.max() / jnp.clip(imp_e.min(), 1e-9)
                ).astype(jnp.float32),
                "token_contribution_min": contrib.min().astype(jnp.float32),
                "token_contribution_max": contrib.max().astype(jnp.float32),
                # per-sequence rows (b,) for the batch-variance probe:
                # Soft-MoE softmaxes are per-row, so these should NOT move
                # with batch composition — the probe's null hypothesis
                "rows": {
                    "dispatch_entropy": dent.mean(axis=1).astype(
                        jnp.float32),
                    "combine_entropy": cent.mean(axis=1).astype(jnp.float32),
                    "token_contribution_min": contrib.min(axis=1).astype(
                        jnp.float32),
                },
            },
        )
    return y, metrics


def _dense_routing_health(d_w, c_weights):
    """Dense oracle for the kernel's routing_health reductions.

    d_w/c_weights: (b, m, n, p) softmax weights. Returns the same
    (disp_entropy (b, S), importance (b, S), comb_entropy (b, m),
    token_contrib (b, m)) tuple as ``kernels.ops.routing_health``.
    """
    b, m, n, p = d_w.shape
    d_flat = d_w.reshape(b, m, n * p)
    c_flat = c_weights.reshape(b, m, n * p)

    def _ent(w, axis):
        return -jnp.sum(jnp.where(w > 0, w * jnp.log(jnp.clip(w, 1e-30)),
                                  0.0), axis=axis)

    return (_ent(d_flat, 1), c_flat.sum(1), _ent(c_flat, 2), d_flat.sum(2))
