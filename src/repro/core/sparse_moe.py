"""Sparse MoE baselines the paper compares against (§3.2):

  * Tokens Choice — top-K router (Shazeer et al. 2017) with optional Batch
    Priority Routing (Riquelme et al. 2021) and capacity buffers.
  * Experts Choice — top-C tokens per expert (Zhou et al. 2022).

Both use scatter/gather buffers of shape (experts, capacity, d) — never the
(tokens × experts × capacity) one-hot tensor — so memory stays linear.
These are also the *native* routers of the assigned MoE archs
(deepseek-v2-lite: top-6 of 64; granite: top-8 of 32), with capacity
buffers sized by `capacity_factor`.

Routing scope is mode-dependent (the batch-invariant serving contract):

* ``mode="train"`` (or ``MoEConfig.batch_coupled=True`` in any mode):
  groups of ``group_size`` sequences route together and compete for
  per-call capacity buffers — the paper's §3.5 batch-coupled setting,
  byte-identical to what the training runs always did.
* serving modes (``"prefill"`` / ``"decode"``): routing is a PURE PER-ROW
  FUNCTION. Each sequence routes alone (group of one) with a dropless
  per-request slot budget (``capacity = tokens-in-this-call`` — the worst
  case for one expert, since top-k choices within a token are distinct),
  so a request's outputs never depend on which rows share the batch, how
  the prompt was chunked, or how many speculative positions ride in the
  call. ``serve.batch_variance_probe`` is the measurement of this
  invariant and must read ~0 on every served arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers.common import lecun_init, split_rngs
from ..layers.mlp import expert_init, experts_apply


def sparse_moe_init(rng, d_model: int, moe_cfg, style: str = "gated"):
    r_r, r_e = split_rngs(rng, 2)
    d_ff = moe_cfg.expert_d_ff
    params = {
        "router": lecun_init(r_r, (d_model, moe_cfg.num_experts), fan_in=d_model),
        "experts": expert_init(r_e, moe_cfg.num_experts, d_model, d_ff, style),
    }
    if moe_cfg.num_shared_experts:
        params["shared"] = expert_init(
            jax.random.fold_in(r_e, 1), moe_cfg.num_shared_experts, d_model,
            d_ff, style,
        )
    return params


def _router_logits(params, x):
    return jnp.einsum(
        "gtd,de->gte", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )


def _routing_scope(moe_cfg, mode: str, b: int, m: int):
    """(coupled, gs, capacity_fn) for the requested mode.

    ``coupled`` group routing spans ``group_size`` sequences and sizes
    buffers by ``capacity_factor`` (tokens compete, overflow drops).
    Per-row serving routing fixes the group at ONE sequence and the
    budget at the dropless bound, making the route of every token a
    function of that token's row alone.
    """
    coupled = moe_cfg.batch_coupled or mode == "train"
    gs = max(1, min(moe_cfg.group_size, b)) if coupled else 1
    return coupled, gs


def _aux_losses(logits, probs, expert_index, num_experts, moe_cfg):
    """Switch-style load-balance loss + router z-loss."""
    # fraction of tokens routed (first choice) to each expert
    onehot = jax.nn.one_hot(expert_index[..., 0], num_experts)
    frac_tokens = onehot.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    balance = num_experts * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return (
        moe_cfg.aux_loss_weight * balance
        + moe_cfg.router_z_loss_weight * z
    )


def _router_telemetry(probs):
    """ST-MoE-style router health: per-token entropy and confidence.
    Returns (scalar dict, per-token entropy (g, t)) — callers derive
    per-sequence rows from the entropy."""
    ent = -jnp.sum(
        jnp.where(probs > 0, probs * jnp.log(jnp.clip(probs, 1e-30)), 0.0),
        axis=-1,
    )
    return {
        "router_entropy": ent.mean().astype(jnp.float32),
        "max_router_prob": probs.max().astype(jnp.float32),
    }, ent


def tokens_choice_apply(params, moe_cfg, x, act: str = "silu",
                        telemetry: bool = False, mode: str = "train"):
    """Top-K token-choice routing. x: (b, m, d).

    ``mode="train"`` (or ``batch_coupled=True``): groups of ``group_size``
    sequences route together (paper §3.5: tokens in a group compete for
    expert buffer slots — the source of batch effects Soft MoE avoids).
    Serving modes route each row alone with a dropless slot budget —
    see the module docstring for the invariant.

    ``telemetry=True`` adds ``metrics["telemetry"]``: router
    entropy/confidence, per-expert load spread over the *kept* choices,
    and kept/dropped fractions — all ``stop_gradient``'d f32 values with
    per-sequence ``rows`` (b,) views, no effect on ``y``.
    """
    b, m, d = x.shape
    coupled, gs = _routing_scope(moe_cfg, mode, b, m)
    g = b // gs
    xg = x.reshape(g, gs * m, d)
    t = gs * m  # tokens per group
    e, k = moe_cfg.num_experts, moe_cfg.top_k

    logits = _router_logits(params, xg)  # (g,t,e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_index = jax.lax.top_k(probs, k)  # (g,t,k)

    if coupled:
        capacity = max(int(moe_cfg.capacity_factor * k * t / e), 1)
    else:
        # Dropless per-request budget: top-k expert ids within a token are
        # distinct, so one expert receives at most t (= tokens in this
        # call) assignments from one row. Decode (m=1) buffers are (e,1,d);
        # a chunked-prefill or (k+1)-verify call budgets exactly its own
        # tokens — never the co-batched rows'.
        capacity = t

    # Priority order over tokens: BPR sorts by max router prob (descending);
    # otherwise positional order. The ORDER is discrete — stop_gradient
    # keeps autodiff from differentiating the sort keys (whose transpose
    # rule lowers to a batched gather this jax build cannot lower). With a
    # dropless budget every (token, choice) lands in a unique buffer slot,
    # so priority is a no-op permutation — per-row serving skips the sort.
    if moe_cfg.bpr and coupled:
        priority = jnp.argsort(
            jax.lax.stop_gradient(-gate[..., 0]), axis=-1
        )  # (g,t)
    else:
        priority = jnp.broadcast_to(jnp.arange(t), (g, t))
    inv = jnp.argsort(priority, axis=-1)  # rank of each token

    # Position of each (token, choice) within its expert buffer, counted in
    # priority order; choices beyond capacity are dropped.
    sorted_idx = jnp.take_along_axis(
        expert_index, priority[..., None], axis=1
    )  # (g,t,k) expert ids in priority order
    flat_choice = jax.nn.one_hot(sorted_idx, e, dtype=jnp.int32)  # (g,t,k,e)
    # order choices within a token by k; cumulative count per expert
    cts = flat_choice.reshape(g, t * k, e)
    pos_sorted = jnp.cumsum(cts, axis=1) - cts  # (g, t*k, e)
    pos_sorted = (pos_sorted * cts).sum(-1).reshape(g, t, k)
    # un-sort back to token order
    pos = jnp.take_along_axis(pos_sorted, inv[..., None], axis=1)
    keep = pos < capacity  # (g,t,k) — all True on the dropless path

    gate = gate * keep
    # normalize kept gates (standard top-k renorm)
    denom = jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    gate_n = gate / denom

    # scatter tokens into (e, capacity, d) buffers per group
    def route_group(xg_g, eidx, posg, keepg, gateg):
        buf = jnp.zeros((e, capacity, d), xg_g.dtype)
        tok_rep = jnp.repeat(jnp.arange(t), k)
        ef = eidx.reshape(-1)
        pf = jnp.where(keepg.reshape(-1), posg.reshape(-1), capacity)
        buf = buf.at[ef, jnp.clip(pf, 0, capacity - 1)].add(
            jnp.where(keepg.reshape(-1)[:, None], xg_g[tok_rep], 0.0)
        )
        out = experts_apply(params["experts"], buf, act)  # (e,cap,d)
        y = out[ef, jnp.clip(pf, 0, capacity - 1)]  # (t*k, d)
        y = jnp.where(keepg.reshape(-1)[:, None], y, 0.0)
        y = (y.reshape(t, k, d) * gateg[..., None]).sum(1)
        return y

    y = jax.vmap(route_group)(xg, expert_index, pos, keep, gate_n)
    y = y.reshape(b, m, d).astype(x.dtype)

    if moe_cfg.num_shared_experts:
        # reshape once; experts_apply broadcasts the leading expert axis
        # (no (num_shared × b·m × d) materialization).
        sh = experts_apply(params["shared"], x.reshape(1, b * m, d), act)
        y = y + sh.sum(0).reshape(b, m, d).astype(x.dtype)

    aux = _aux_losses(logits, probs, expert_index, e, moe_cfg)
    # per-row fully-dropped token fraction (b,): rows never mix, matching
    # the per-request capacity accounting (0 everywhere on the dropless
    # serving path); the scalar is its mean.
    dropped_rows = 1.0 - keep.any(axis=-1).reshape(g, gs, m).mean(
        axis=2).reshape(b)
    dropped = dropped_rows.mean()
    metrics = {"moe_aux_loss": aux, "dropped_fraction": dropped}
    if telemetry:
        # per-expert load over KEPT (token, choice) assignments — the
        # capacity-competition outcome the batch-variance probe watches
        load = (jax.nn.one_hot(expert_index, e) * keep[..., None]).sum(
            axis=(0, 1, 2))  # (e,)
        scalars, ent = _router_telemetry(probs)
        metrics["telemetry"] = jax.tree_util.tree_map(
            jax.lax.stop_gradient,
            {
                **scalars,
                "expert_load_spread": load.max() / jnp.clip(load.min(), 1e-9),
                "kept_fraction": keep.mean().astype(jnp.float32),
                "dropped_fraction": dropped.astype(jnp.float32),
                # per-sequence rows (b,): the batch-variance probe compares
                # the target row solo vs co-batched; each row's stats are a
                # function of that row alone under per-row serving routing
                "rows": {
                    "router_entropy": ent.reshape(g, gs, m).mean(
                        axis=2).reshape(b).astype(jnp.float32),
                    "kept_fraction": keep.reshape(g, gs, m, k).mean(
                        axis=(2, 3)).reshape(b).astype(jnp.float32),
                    "dropped_fraction": dropped_rows.astype(jnp.float32),
                },
            },
        )
    return y, metrics


def experts_choice_apply(params, moe_cfg, x, act: str = "silu",
                         telemetry: bool = False, mode: str = "train"):
    """Experts-Choice routing: each expert takes its top-C tokens.

    Serving modes scope the selection within a single row (group of one)
    with the dropless budget ``capacity = tokens-in-this-call``: every
    expert then takes every token of the row, weighted by its router
    prob — the continuous limit of experts-choice, and the only
    batch-size-independent member of its family (selection across rows is
    inherently batch-coupled). ``mode="train"`` / ``batch_coupled=True``
    keep the paper's competitive top-C selection.
    """
    b, m, d = x.shape
    coupled, gs = _routing_scope(moe_cfg, mode, b, m)
    g = b // gs
    xg = x.reshape(g, gs * m, d)
    t = gs * m
    e = moe_cfg.num_experts

    logits = _router_logits(params, xg)  # (g,t,e)
    probs = jax.nn.softmax(logits, axis=-1)
    if coupled:
        capacity = max(int(moe_cfg.capacity_factor * t / e), 1)
    else:
        capacity = t  # dropless: every expert can take the whole row

    # per expert: top-capacity tokens
    scores = probs.transpose(0, 2, 1)  # (g,e,t)
    gsc, tidx = jax.lax.top_k(scores, capacity)  # (g,e,cap)

    def route_group(xg_g, tidx_g, gsc_g):
        gathered = xg_g[tidx_g.reshape(-1)].reshape(e, capacity, d)
        out = experts_apply(params["experts"], gathered, act)
        out = out * gsc_g[..., None].astype(out.dtype)
        y = jnp.zeros((t, d), out.dtype)
        y = y.at[tidx_g.reshape(-1)].add(out.reshape(e * capacity, d))
        return y

    y = jax.vmap(route_group)(xg, tidx, gsc)
    y = y.reshape(b, m, d).astype(x.dtype)

    aux = moe_cfg.router_z_loss_weight * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1))
    )
    # dropped = tokens selected by no expert (paper App. B), per row
    selected = jnp.zeros((g, t), bool).at[
        jnp.arange(g)[:, None, None], tidx
    ].set(True)
    selected_rows = selected.reshape(g, gs, m).mean(axis=2).reshape(b)
    metrics = {
        "moe_aux_loss": aux,
        "dropped_fraction": 1.0 - selected_rows.mean(),
    }
    if telemetry:
        # expert load is uniform by construction (each expert takes exactly
        # `capacity` tokens); token coverage is the health signal instead.
        scalars, ent = _router_telemetry(probs)
        metrics["telemetry"] = jax.tree_util.tree_map(
            jax.lax.stop_gradient,
            {
                **scalars,
                "kept_fraction": selected_rows.mean().astype(jnp.float32),
                "dropped_fraction": (1.0 - selected_rows.mean()).astype(
                    jnp.float32),
                "rows": {
                    "router_entropy": ent.reshape(g, gs, m).mean(
                        axis=2).reshape(b).astype(jnp.float32),
                    "kept_fraction": selected_rows.astype(jnp.float32),
                    "dropped_fraction": (1.0 - selected_rows).astype(
                        jnp.float32),
                },
            },
        )
    return y, metrics
