"""Model inspection (paper §5, Fig. 9 / App. G): statistics of the learned
dispatch/combine weights.

  * token_contributions — total dispatch weight each token sends to all
    slots (Fig. 9 left: heavy-tailed; no token at zero = no dropping).
  * expert_importance — per-slot combine mass summed over tokens,
    normalized by its min (Fig. 9 middle: 3–14× spread across experts).
  * cumulative_slot_weight — how many tokens cover a given fraction of a
    slot's dispatch mass (Fig. 9 right / App. G cumulative curves).

Two paths compute the same statistics:

  * ``method="dense"`` — materializes the (b, m, n·p) weight tensors via
    ``soft_moe_weights``. The oracle: exact, simple, but O(b·m·S) memory,
    so it only runs at offline/figure shapes.
  * ``method="chunked"`` — streams token chunks against per-slot /
    per-token online-softmax ``(max, denom)`` stats (the same residuals
    the Pallas kernels save), so memory is O(chunk·S) and inspection runs
    at serving shapes. ``tokens_for_*pct`` needs a full sort over tokens
    per slot and is dense-only.

``routing_health_from_stats`` is the chunked jnp twin of
``kernels.ops.routing_health`` (the Pallas reduction the serving
telemetry uses) — tests pin all three against each other.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from ..layers.common import l2_normalize
from .soft_moe import soft_moe_weights


def streaming_softmax_stats(x, phi_n, chunk_tokens: int = 512):
    """Per-slot dispatch and per-token combine (max, denom) softmax stats,
    streamed over token chunks — never an (m × S) tensor.

    x: (b, m, d) raw tokens; phi_n: (d, S) pre-normalized (scale folded
    in). Returns ``((d_mx, d_den) each (b, S), (c_mx, c_den) each (b, m))``
    matching ``kernels.soft_moe_kernels.routing_fwd_pallas``'s stats.
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    xn = l2_normalize(x, axis=-1).astype(jnp.float32)
    phi_n = phi_n.astype(jnp.float32)
    chunk = min(chunk_tokens or m, m)
    d_mx = jnp.full((b, s), -jnp.inf, jnp.float32)
    d_den = jnp.zeros((b, s), jnp.float32)
    c_mx_parts, c_den_parts = [], []
    for i in range(0, m, chunk):
        lg = jnp.einsum("bmd,ds->bms", xn[:, i:i + chunk], phi_n)
        # combine direction is self-contained per token row
        cm = lg.max(-1)
        c_mx_parts.append(cm)
        c_den_parts.append(jnp.exp(lg - cm[..., None]).sum(-1))
        # dispatch direction: online (max, denom) update per slot column
        mx_new = jnp.maximum(d_mx, lg.max(1))
        d_den = d_den * jnp.exp(d_mx - mx_new) + jnp.exp(
            lg - mx_new[:, None, :]).sum(1)
        d_mx = mx_new
    return ((d_mx, d_den),
            (jnp.concatenate(c_mx_parts, 1), jnp.concatenate(c_den_parts, 1)))


def routing_health_from_stats(x, phi_n, d_stats, c_stats,
                              chunk_tokens: int = 512):
    """Chunked jnp twin of ``kernels.ops.routing_health``.

    Recomputes logits chunk-wise against the saved ``(max, denom)`` stats
    and reduces to ``(disp_entropy (b, S), importance (b, S),
    comb_entropy (b, m), token_contrib (b, m))``.
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    d_mx, d_den = d_stats
    c_mx, c_den = c_stats
    xn = l2_normalize(x, axis=-1).astype(jnp.float32)
    phi_n = phi_n.astype(jnp.float32)
    chunk = min(chunk_tokens or m, m)
    dent = jnp.zeros((b, s), jnp.float32)
    imp = jnp.zeros((b, s), jnp.float32)
    cent_parts, contrib_parts = [], []
    log_dden = jnp.log(d_den.astype(jnp.float32))
    for i in range(0, m, chunk):
        lg = jnp.einsum("bmd,ds->bms", xn[:, i:i + chunk], phi_n)
        ln_d = lg - d_mx[:, None, :].astype(jnp.float32) - log_dden[:, None]
        d_w = jnp.exp(ln_d)
        dent = dent - jnp.sum(d_w * ln_d, axis=1)
        contrib_parts.append(d_w.sum(-1))
        cm = c_mx[:, i:i + chunk].astype(jnp.float32)
        cd = c_den[:, i:i + chunk].astype(jnp.float32)
        ln_c = lg - cm[..., None] - jnp.log(cd)[..., None]
        c_w = jnp.exp(ln_c)
        cent_parts.append(-jnp.sum(c_w * ln_c, axis=-1))
        imp = imp + c_w.sum(1)
    return (dent, imp, jnp.concatenate(cent_parts, 1),
            jnp.concatenate(contrib_parts, 1))


def routing_stats(x, params, method: str = "dense",
                  chunk_tokens: int = 512) -> Dict[str, jnp.ndarray]:
    """x: (b, m, d); params: a Soft-MoE layer's params.

    ``method="dense"`` is the (b, m, n·p)-materializing oracle;
    ``method="chunked"`` computes the same statistics from streamed
    softmax stats at O(chunk·S) memory (serving shapes), minus the
    sort-based ``tokens_for_*pct`` curves.
    """
    if method == "chunked":
        return _routing_stats_chunked(x, params, chunk_tokens)
    if method != "dense":
        raise ValueError(f"unknown routing_stats method {method!r}")
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    b, m, n, p = d_w.shape
    d_flat = d_w.reshape(b, m, n * p)
    c_flat = c_w.reshape(b, m, n * p)

    token_contrib = d_flat.sum(-1)  # (b, m): summed dispatch per token
    expert_importance = c_flat.sum(1)  # (b, S): combine mass per slot
    expert_importance = expert_importance / jnp.maximum(
        expert_importance.min(axis=-1, keepdims=True), 1e-9
    )

    def _ent(w, axis):
        return -jnp.sum(
            jnp.where(w > 0, w * jnp.log(jnp.clip(w, 1e-30)), 0.0), axis=axis
        )

    # cumulative dispatch: sort each slot's weights desc, cumsum over tokens
    sorted_w = -jnp.sort(-d_flat, axis=1)  # (b, m, S) desc over tokens
    cum = jnp.cumsum(sorted_w, axis=1)

    def tokens_to_cover(frac):
        covered = cum >= frac  # (b, m, S)
        return covered.argmax(axis=1) + 1  # first index reaching frac

    return {
        "token_contribution": token_contrib,
        "token_contribution_max": token_contrib.max(),
        "token_contribution_min": token_contrib.min(),
        "expert_importance": expert_importance,
        "expert_importance_spread": expert_importance.max(-1).mean(),
        "tokens_for_50pct": tokens_to_cover(0.5),
        "tokens_for_90pct": tokens_to_cover(0.9),
        "max_dispatch_weight": d_w.max(),
        "max_combine_weight": c_w.max(),
        "dispatch_entropy": _ent(d_flat, 1).mean(),
        "combine_entropy": _ent(c_flat, 2).mean(),
    }


def _routing_stats_chunked(x, params, chunk_tokens: int) -> Dict[str, jnp.ndarray]:
    from ..kernels import ref

    d = params["phi"].shape[0]
    phi_n = ref.normalized_phi(params["phi"].reshape(d, -1), params["scale"])
    d_stats, c_stats = streaming_softmax_stats(x, phi_n, chunk_tokens)
    dent, imp, cent, contrib = routing_health_from_stats(
        x, phi_n, d_stats, c_stats, chunk_tokens)
    expert_importance = imp / jnp.maximum(
        imp.min(axis=-1, keepdims=True), 1e-9)
    return {
        "token_contribution": contrib,
        "token_contribution_max": contrib.max(),
        "token_contribution_min": contrib.min(),
        "expert_importance": expert_importance,
        "expert_importance_spread": expert_importance.max(-1).mean(),
        # max softmax weight per column/row falls out of the saved stats:
        # exp(mx − mx)/den = 1/den
        "max_dispatch_weight": (1.0 / d_stats[1]).max(),
        "max_combine_weight": (1.0 / c_stats[1]).max(),
        "dispatch_entropy": dent.mean(),
        "combine_entropy": cent.mean(),
    }


def summarize(stats: Dict[str, jnp.ndarray]) -> Dict[str, float]:
    out = {}
    for k, v in stats.items():
        arr = jnp.asarray(v)
        if arr.ndim == 0:
            out[k] = float(arr)
        else:
            out[f"{k}_mean"] = float(arr.mean())
            out[f"{k}_p90"] = float(jnp.percentile(arr.astype(jnp.float32),
                                                   90))
    return out
