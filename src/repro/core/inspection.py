"""Model inspection (paper §5, Fig. 9 / App. G): statistics of the learned
dispatch/combine weights.

  * token_contributions — total dispatch weight each token sends to all
    slots (Fig. 9 left: heavy-tailed; no token at zero = no dropping).
  * expert_importance — per-slot combine mass summed over tokens,
    normalized by its min (Fig. 9 middle: 3–14× spread across experts).
  * cumulative_slot_weight — how many tokens cover a given fraction of a
    slot's dispatch mass (Fig. 9 right / App. G cumulative curves).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from .soft_moe import soft_moe_weights


def routing_stats(x, params, moe_cfg) -> Dict[str, jnp.ndarray]:
    """x: (b, m, d); params: a Soft-MoE layer's params."""
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    b, m, n, p = d_w.shape
    d_flat = d_w.reshape(b, m, n * p)
    c_flat = c_w.reshape(b, m, n * p)

    token_contrib = d_flat.sum(-1)  # (b, m): summed dispatch per token
    expert_importance = c_flat.sum(1)  # (b, S): combine mass per slot
    expert_importance = expert_importance / jnp.maximum(
        expert_importance.min(axis=-1, keepdims=True), 1e-9
    )

    # cumulative dispatch: sort each slot's weights desc, cumsum over tokens
    sorted_w = -jnp.sort(-d_flat, axis=1)  # (b, m, S) desc over tokens
    cum = jnp.cumsum(sorted_w, axis=1)

    def tokens_to_cover(frac):
        covered = cum >= frac  # (b, m, S)
        return covered.argmax(axis=1) + 1  # first index reaching frac

    return {
        "token_contribution": token_contrib,
        "token_contribution_max": token_contrib.max(),
        "token_contribution_min": token_contrib.min(),
        "expert_importance": expert_importance,
        "expert_importance_spread": expert_importance.max(-1).mean(),
        "tokens_for_50pct": tokens_to_cover(0.5),
        "tokens_for_90pct": tokens_to_cover(0.9),
        "max_dispatch_weight": d_w.max(),
        "max_combine_weight": c_w.max(),
    }


def summarize(stats: Dict[str, jnp.ndarray]) -> Dict[str, float]:
    out = {}
    for k, v in stats.items():
        arr = jnp.asarray(v)
        if arr.ndim == 0:
            out[k] = float(arr)
        else:
            out[f"{k}_mean"] = float(arr.mean())
            out[f"{k}_p90"] = float(jnp.percentile(arr.astype(jnp.float32),
                                                   90))
    return out
