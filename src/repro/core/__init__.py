"""MoE core: Soft MoE (the paper's technique), sparse baselines, ablations.

``moe_init`` / ``moe_apply`` dispatch on ``MoEConfig.variant`` so models
treat every router uniformly.
"""
from __future__ import annotations

from .ablations import ablation_apply, ablation_init  # noqa: F401
from .soft_moe import soft_moe_apply, soft_moe_init, soft_moe_weights  # noqa: F401
from .sparse_moe import (  # noqa: F401
    experts_choice_apply,
    sparse_moe_init,
    tokens_choice_apply,
)

_ABLATIONS = ("identity", "uniform", "soft_uniform", "uniform_soft")


def resolve_moe_cfg(moe_cfg, d_ff_default: int):
    """expert_d_ff == 0 means 'inherit the model d_ff'."""
    import dataclasses

    if moe_cfg.expert_d_ff == 0:
        if d_ff_default <= 0:
            raise ValueError(
                "MoE layer with expert_d_ff=0 needs a model d_ff to inherit"
            )
        return dataclasses.replace(moe_cfg, expert_d_ff=d_ff_default)
    return moe_cfg


def moe_init(rng, d_model: int, moe_cfg, style: str = "gated"):
    assert moe_cfg.expert_d_ff > 0, "resolve expert_d_ff first (block_init)"
    if moe_cfg.variant == "soft" or moe_cfg.variant in _ABLATIONS:
        return soft_moe_init(rng, d_model, moe_cfg, style)
    if moe_cfg.variant in ("tokens_choice", "experts_choice"):
        return sparse_moe_init(rng, d_model, moe_cfg, style)
    raise ValueError(f"unknown MoE variant {moe_cfg.variant!r}")


def moe_apply(params, moe_cfg, x, act: str = "silu",
              use_kernel: bool = False, telemetry: bool = False,
              mode: str = "train"):
    """``telemetry=True`` (a static build flag, never a traced value) adds
    a ``metrics["telemetry"]`` dict of stop_gradient'd routing-health
    scalars on the soft / tokens_choice / experts_choice variants — the
    output ``y`` is unchanged. Ablation variants have no router to probe
    and ignore the flag.

    ``mode`` (static, threaded from ``block_apply``) selects the sparse
    variants' routing scope: ``"train"`` keeps the paper's batch-coupled
    group routing; serving modes (``"prefill"``/``"decode"``) route each
    row independently and droplessly (see core/sparse_moe.py). Soft MoE
    and the ablations are per-row in every mode — their softmaxes never
    cross sequences — so they ignore it."""
    if moe_cfg.variant == "soft":
        return soft_moe_apply(params, moe_cfg, x, act, use_kernel=use_kernel,
                              telemetry=telemetry)
    if moe_cfg.variant in _ABLATIONS:
        return ablation_apply(params, moe_cfg, x, act)
    if moe_cfg.variant == "tokens_choice":
        return tokens_choice_apply(params, moe_cfg, x, act,
                                   telemetry=telemetry, mode=mode)
    if moe_cfg.variant == "experts_choice":
        return experts_choice_apply(params, moe_cfg, x, act,
                                    telemetry=telemetry, mode=mode)
    raise ValueError(f"unknown MoE variant {moe_cfg.variant!r}")
