"""Fixed-routing ablations (paper Table 3 / Appendix A).

  * identity      — token i -> expert i (round-robin); D, C are (normalized)
                    one-hot; equals the identity matrix when m == n·p.
  * uniform       — D = 1/m everywhere, C = 1/(n·p) everywhere.
  * soft_uniform  — learned dispatch D, uniform combine C.
  * uniform_soft  — uniform dispatch D, learned combine C.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers.mlp import experts_apply
from .soft_moe import soft_moe_init, soft_moe_weights


def ablation_init(rng, d_model: int, moe_cfg, style: str = "gated"):
    # Same param structure as Soft MoE (Phi unused by the fixed sides, but
    # kept so checkpoints/configs stay interchangeable).
    return soft_moe_init(rng, d_model, moe_cfg, style)


def _round_robin_dispatch(m: int, n: int, p: int):
    """D[t, slot] one-hot on slot = t mod (n·p), normalized per slot."""
    slots = n * p
    assign = jnp.arange(m) % slots
    d = jax.nn.one_hot(assign, slots)  # (m, slots)
    d = d / jnp.clip(d.sum(0, keepdims=True), 1.0)
    return d.reshape(m, n, p)


def _round_robin_combine(m: int, n: int, p: int):
    """C[t, slot]: token t combines slot t mod (n·p) only."""
    slots = n * p
    assign = jnp.arange(m) % slots
    return jax.nn.one_hot(assign, slots).reshape(m, n, p)


def ablation_apply(params, moe_cfg, x, act: str = "silu"):
    b, m, d = x.shape
    n, p = moe_cfg.num_experts, moe_cfg.slots_per_expert
    variant = moe_cfg.variant

    learned_d, learned_c = None, None
    if variant in ("soft_uniform", "uniform_soft"):
        learned_d, learned_c = soft_moe_weights(
            x, params["phi"], params["scale"]
        )

    if variant == "identity":
        d_w = jnp.broadcast_to(_round_robin_dispatch(m, n, p), (b, m, n, p))
        c_w = jnp.broadcast_to(_round_robin_combine(m, n, p), (b, m, n, p))
    elif variant == "uniform":
        d_w = jnp.full((b, m, n, p), 1.0 / m)
        c_w = jnp.full((b, m, n, p), 1.0 / (n * p))
    elif variant == "soft_uniform":  # learned dispatch / uniform combine
        d_w = learned_d
        c_w = jnp.full((b, m, n, p), 1.0 / (n * p))
    elif variant == "uniform_soft":  # uniform dispatch / learned combine
        d_w = jnp.full((b, m, n, p), 1.0 / m)
        c_w = learned_c
    else:
        raise ValueError(f"unknown ablation variant {variant!r}")

    slots = jnp.einsum("bmd,bmnp->bnpd", x.astype(jnp.float32), d_w)
    ys = slots.astype(x.dtype).transpose(1, 0, 2, 3).reshape(n, b * p, d)
    ys = experts_apply(params["experts"], ys, act)
    ys = ys.reshape(n, b, p, d).transpose(1, 0, 2, 3)
    y = jnp.einsum("bnpd,bmnp->bmd", ys.astype(jnp.float32), c_w)
    return y.astype(x.dtype), {"moe_aux_loss": jnp.zeros((), jnp.float32)}
