"""Gated MLP (SwiGLU/GeGLU) — also the expert function used by every MoE
variant (the paper's experts are MLPs)."""
from __future__ import annotations

import jax.numpy as jnp

from .common import activation, lecun_init, split_rngs


def mlp_init(rng, d_model: int, d_ff: int, style: str = "gated"):
    r1, r2, r3 = split_rngs(rng, 3)
    p = {
        "w_up": lecun_init(r2, (d_model, d_ff), fan_in=d_model),
        "w_down": lecun_init(r3, (d_ff, d_model), fan_in=d_ff),
    }
    if style == "gated":
        p["w_gate"] = lecun_init(r1, (d_model, d_ff), fan_in=d_model)
    return p


def mlp_apply(params, x, act: str = "silu"):
    dt = x.dtype
    f = activation(act)
    up = x @ params["w_up"].astype(dt)
    if "w_gate" in params:  # SwiGLU
        h = f(x @ params["w_gate"].astype(dt)) * up
    else:  # classic fc1-act-fc2 (the paper's ViT MLP)
        h = f(up)
    return h @ params["w_down"].astype(dt)


def expert_init(rng, num_experts: int, d_model: int, d_ff: int,
                style: str = "gated"):
    """Stacked expert params: leading axis = expert."""
    assert d_ff > 0, (
        "expert_d_ff resolved to 0 — zero-width experts. MoEConfig uses "
        "0 as 'inherit model d_ff'; resolve before init (moe_init does)."
    )
    r1, r2, r3 = split_rngs(rng, 3)
    p = {
        "w_up": lecun_init(r2, (num_experts, d_model, d_ff), fan_in=d_model),
        "w_down": lecun_init(r3, (num_experts, d_ff, d_model), fan_in=d_ff),
    }
    if style == "gated":
        p["w_gate"] = lecun_init(
            r1, (num_experts, d_model, d_ff), fan_in=d_model
        )
    return p


def experts_apply(params, xs, act: str = "silu"):
    """xs: (num_experts | 1, slots_or_capacity, d) -> (num_experts, s, d).
    Batched matmuls so a leading 1 broadcasts against the expert axis
    (shared-expert path feeds every expert the same tokens without a
    caller-side ``broadcast_to`` materialization); the expert axis stays
    leading so it shards over the `model` mesh axis (expert parallelism)."""
    dt = xs.dtype
    f = activation(act)
    up = xs @ params["w_up"].astype(dt)  # (1|E, s, d) @ (E, d, f)
    if "w_gate" in params:
        h = f(xs @ params["w_gate"].astype(dt)) * up
    else:
        h = f(up)
    return h @ params["w_down"].astype(dt)
