"""Shared layer plumbing: params are plain pytrees (nested dicts of jnp
arrays); every layer exposes ``init(rng, cfg, ...) -> params`` and
``apply(params, cfg, x, ...) -> y`` pure functions (no framework)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def truncated_normal(rng, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def lecun_init(rng, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return truncated_normal(rng, shape, (1.0 / max(fan_in, 1)) ** 0.5, dtype)


def split_rngs(rng, n: int):
    return list(jax.random.split(rng, n))


def cast(x, dtype_str: str):
    return x.astype(jnp.dtype(dtype_str))


def compute_cast(params, dtype_str: str):
    """Cast float params to the compute dtype (mixed precision)."""
    dt = jnp.dtype(dtype_str)

    def _c(p):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dt)
        return p

    return jax.tree_util.tree_map(_c, params)


# --- normalization ----------------------------------------------------------


def norm_init(cfg, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.ones((d,))}


def _mean_sq(x):
    """Mean of squares with f32 ACCUMULATION but without materializing an
    f32 copy of x (einsum with preferred_element_type). The obvious
    x.astype(f32) materializes — and XLA-CPU hoists that convert out of
    the reverse-scan loop, pinning an f32 copy of the whole per-layer
    activation stash (10.7GB/device at qwen2-72b train_4k)."""
    ms = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    )
    return ms[..., None] / x.shape[-1]


def norm_apply(params, cfg, x, eps: float = 1e-6):
    """Stats in f32, application in the compute dtype (bf16-safe)."""
    dtype = x.dtype
    if cfg.norm == "layernorm":
        mu = (
            jnp.einsum("...d->...", x, preferred_element_type=jnp.float32)[
                ..., None
            ]
            / x.shape[-1]
        )
        xc = x - mu.astype(dtype)
        var = _mean_sq(xc)
        inv = jax.lax.rsqrt(var + eps).astype(dtype)
        y = xc * inv
        y = y * params["scale"].astype(dtype) + params["bias"].astype(dtype)
    else:  # rmsnorm
        ms = _mean_sq(x)
        inv = jax.lax.rsqrt(ms + eps).astype(dtype)
        y = x * inv * params["scale"].astype(dtype)
    return y


def l2_normalize(x, axis, eps: float = 1e-6):
    """Paper Algorithm 2, verbatim semantics."""
    norm = jnp.sqrt(jnp.sum(jnp.square(x), axis=axis, keepdims=True))
    return x * jnp.reciprocal(norm + eps)


# --- activations ------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[
        name
    ]


# --- misc -------------------------------------------------------------------


def stack_pytrees(trees: Sequence):
    """Stack a list of identical-structure pytrees along a new axis 0
    (layer-stacking for scan-over-layers)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
