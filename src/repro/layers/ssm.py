"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked matmul form ("matrix transformer"): the
sequence is split into chunks; intra-chunk terms are dense masked matmuls
(MXU-friendly) and inter-chunk terms run one small recurrence over chunk
states via lax.scan. Decode keeps a constant-size recurrent state
(B, heads, head_dim, state) + a causal-conv ring state — this is what makes
the long_500k shape sub-quadratic for SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import lecun_init, split_rngs


def ssm_init(rng, cfg):
    """Separate projections per role (z/x/B/C/dt) rather than one packed
    in_proj: each can then be sharded on a head/group-aligned axis for
    tensor parallelism without shard boundaries straddling roles."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    conv_ch = di + 2 * s.ngroups * s.state_dim
    rs = split_rngs(rng, 7)
    return {
        "w_z": lecun_init(rs[0], (d, di), fan_in=d),
        "w_x": lecun_init(rs[1], (d, di), fan_in=d),
        "w_B": lecun_init(rs[2], (d, s.ngroups * s.state_dim), fan_in=d),
        "w_C": lecun_init(rs[3], (d, s.ngroups * s.state_dim), fan_in=d),
        "w_dt": lecun_init(rs[4], (d, nh), fan_in=d),
        "conv_w": lecun_init(rs[5], (s.conv_width, conv_ch), fan_in=s.conv_width),
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh)
        ),  # A in [-16, -1]
        "D": jnp.ones((nh,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2) * 100.0)),
        "norm_scale": jnp.ones((di,)),
        "w_out": lecun_init(rs[6], (di, d), fan_in=di),
    }


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d. xbc: (B,S,C). If conv_state (B,W-1,C) given,
    prepend it (decode/prefill continuation); returns (out, new_state)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (w - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, S+W-1, C)
    # depthwise conv as sum of shifted slices (W is tiny: 4)
    s = xbc.shape[1]
    out = sum(
        full[:, i : i + s] * conv_w[i].astype(xbc.dtype) for i in range(w)
    )
    out = jax.nn.silu(out + conv_b.astype(xbc.dtype))
    new_state = full[:, -(w - 1) :] if w > 1 else pad[:, :0]
    return out, new_state


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k]
    (lower-triangular), -inf above the diagonal."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD scan in chunked matmul form.

    x: (b,s,h,dh); dt: (b,s,h) (post-softplus); A: (h,) negative;
    B, C: (b,s,g,n). Returns (y: (b,s,h,dh), final_state: (b,h,dh,n)).
    """
    b, s, h, dh = x.shape
    g, n = B.shape[2], B.shape[3]
    orig_s = s
    if s % chunk != 0:
        # Pad with dt=0 tokens: decay exp(0)=1 and contribution dt·B·x=0,
        # so padding is exact (state and outputs unaffected).
        pad = (s + chunk - 1) // chunk * chunk - s
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    nc = s // chunk
    hpg = h // g  # heads per B/C group

    f32 = jnp.float32
    xc = (x * dt[..., None]).astype(f32).reshape(b, nc, chunk, h, dh)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, chunk, h)
    Bc = B.astype(f32).reshape(b, nc, chunk, g, n)
    Cc = C.astype(f32).reshape(b, nc, chunk, g, n)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, hpg, axis=3)  # (b,nc,l,h,n)
    Ch = jnp.repeat(Cc, hpg, axis=3)

    dA_cs = jnp.cumsum(dA, axis=2)  # (b,nc,l,h)

    # 1) intra-chunk (block-diagonal) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b,nc,h,l,l)
    scores = jnp.einsum("bclhn,bcthn->bchlt", Ch, Bh) * L
    y_diag = jnp.einsum("bchlt,bcthd->bclhd", scores, xc)

    # 2) chunk states: state_c = sum_t exp(dA_cs[-1]-dA_cs[t]) B_t x_t^T
    decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclhd->bchdn", Bh, decay, xc)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b,nc,h)

    def step(carry, inp):
        st, dec = inp  # (b,h,dh,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = (
        jnp.zeros((b, h, dh, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,dh,n)

    # 4) inter-chunk output: C_t · (decay-to-t · state_in)
    state_decay = jnp.exp(dA_cs)  # (b,nc,l,h)
    y_off = jnp.einsum(
        "bclhn,bclh,bchdn->bclhd", Ch, state_decay, prev_states
    )

    y = (y_diag + y_off).reshape(b, s, h, dh)[:, :orig_s]
    return y, final


def ssm_apply(params, cfg, x, *, cache=None, mode: str = "train",
              positions=None):
    """Full Mamba-2 block. cache: {"conv": (B,W-1,C), "state": (B,h,dh,n)}
    or None. Returns (out, new_cache).

    ``positions`` ((S,) or (B,S)) is only consulted on cached paths: tokens
    with position < 0 (chunked-prefill left-pad, inactive serving rows) are
    exact no-ops on the recurrent state — their dt is forced to 0 (decay
    exp(0)=1, contribution dt·B·x=0) and their conv-tap input zeroed; rows
    with no valid token keep their conv ring unshifted."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.num_heads(d)
    dh = s_cfg.head_dim
    gn = s_cfg.ngroups * s_cfg.state_dim
    dt_ = x.dtype

    z = x @ params["w_z"].astype(dt_)
    xbc = jnp.concatenate(
        [x @ params["w_x"].astype(dt_), x @ params["w_B"].astype(dt_),
         x @ params["w_C"].astype(dt_)], axis=-1,
    )
    dt_raw = x @ params["w_dt"].astype(dt_)

    valid = None
    if cache is not None and positions is not None:
        valid = positions >= 0  # (S,) or (B,S)
        if valid.ndim == 1:
            valid = jnp.broadcast_to(valid[None], x.shape[:2])
        xbc = xbc * valid[..., None].astype(xbc.dtype)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], conv_state
    )
    x_ssm, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    b_, s_, _ = x_ssm.shape
    x_ssm = x_ssm.reshape(b_, s_, nh, dh)
    B = B.reshape(b_, s_, s_cfg.ngroups, s_cfg.state_dim)
    C = C.reshape(b_, s_, s_cfg.ngroups, s_cfg.state_dim)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    if valid is not None:
        dt = dt * valid[..., None]  # dt=0 => state update is a no-op
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    init_state = cache["state"] if cache is not None else None
    if mode == "decode" and s_ == 1:
        y, new_state = ssd_decode_step(x_ssm, dt, A, B, C, init_state)
    else:
        chunk = min(s_cfg.chunk_size, s_)
        y, new_state = ssd_chunked(x_ssm, dt, A, B, C, chunk, init_state)

    y = y + x_ssm.astype(jnp.float32) * params["D"].astype(jnp.float32)[
        :, None
    ]
    y = y.reshape(b_, s_, di).astype(dt_)
    # gated RMSNorm (mamba2 places it before out_proj); stats in f32 via
    # dot accumulation, application in compute dtype (see common._mean_sq)
    y = y * jax.nn.silu(z)
    ms = jnp.einsum(
        "...d,...d->...", y, y, preferred_element_type=jnp.float32
    )[..., None] / y.shape[-1]
    y = y * jax.lax.rsqrt(ms + 1e-6).astype(dt_)
    y = y * params["norm_scale"].astype(dt_)
    out = y @ params["w_out"].astype(dt_)

    new_cache = None
    if cache is not None:
        if valid is not None:
            # A row with zero valid tokens must not shift its conv ring
            # (dt=0 already freezes `state`; the conv shift has no such
            # algebraic no-op, so predicate per row).
            row_live = valid.any(axis=1)  # (B,)
            new_conv = jnp.where(
                row_live[:, None, None], new_conv.astype(cache["conv"].dtype),
                cache["conv"],
            )
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": new_state.astype(cache["state"].dtype)}
    return out, new_cache


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token recurrent update. x: (b,1,h,dh); state: (b,h,dh,n)."""
    b, _, h, dh = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    f32 = jnp.float32
    x0 = x[:, 0].astype(f32)  # (b,h,dh)
    dt0 = dt[:, 0]  # (b,h)
    B0 = jnp.repeat(B[:, 0].astype(f32), hpg, axis=1)  # (b,h,n)
    C0 = jnp.repeat(C[:, 0].astype(f32), hpg, axis=1)
    decay = jnp.exp(dt0 * A)  # (b,h)
    state = jnp.zeros((b, h, dh, n), f32) if state is None else state.astype(f32)
    new_state = state * decay[..., None, None] + jnp.einsum(
        "bhd,bh,bhn->bhdn", x0, dt0, B0
    )
    y = jnp.einsum("bhdn,bhn->bhd", new_state, C0)[:, None]  # (b,1,h,dh)
    return y, new_state


def reset_ssm_rows(cache, row):
    """Zero row(s) of an SSM cache — unlike KV entries there is no position
    mask guarding stale state, so slot reuse must scrub it explicitly."""
    return {"conv": cache["conv"].at[row].set(0),
            "state": cache["state"].at[row].set(0)}


def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    conv_ch = di + 2 * s.ngroups * s.state_dim
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
    }
