"""Token embedding / unembedding (vocab sharded over the `model` axis)."""
from __future__ import annotations

import jax.numpy as jnp

from ..distributed.api import constrain
from .common import truncated_normal


def embedding_init(rng, vocab_size: int, d_model: int):
    return {"table": truncated_normal(rng, (vocab_size, d_model), 0.02)}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x, softcap: float = 0.0):
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32),
        params["table"].astype(jnp.float32),
    )
    # Pin the vocab axis to `model`: without this GSPMD may decide the
    # logits (and, worse, their cotangent in the tied-embedding backward)
    # are replicated over model — a (tokens × full-vocab) f32 tensor,
    # ~40GB/device at the 152k-vocab train_4k cell.
    logits = constrain(logits, "batch", None, "model")
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
