"""Attention variants: GQA (optional QKV bias, sliding window) and MLA
(DeepSeek-V2 multi-head latent attention with compressed KV cache).

Three execution modes share one code path:
  * train/eval: full sequence, no cache.
  * prefill:    full sequence, cache written for subsequent decoding.
  * decode:     q_len==1, attends over the cache (ring buffer for
                sliding-window layers, absorbed-matmul form for MLA).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.paged_attention_kernels import paged_decode_attend
from .common import lecun_init, split_rngs
from .rotary import apply_rope

NEG_INF = -2.0**30

# Above this (Sq * Sk) product, attention switches to the flash-style
# chunked path — never materializes (Sq, Sk) logits. At train_4k the dense
# path would hold a (b, h, 4096, 4096) f32 logits tensor per device
# (~15GB/dev for qwen2-0.5b, whose 14 heads can't shard over a 16-way
# model axis); the chunked path keeps one (Sq, block) tile live instead.
_CHUNKED_THRESHOLD = 2048 * 4096


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(rng, cfg):
    a = cfg.attention
    d = cfg.d_model
    r_q, r_k, r_v, r_o = split_rngs(rng, 4)
    p = {
        "wq": lecun_init(r_q, (d, a.num_heads, a.head_dim), fan_in=d),
        "wk": lecun_init(r_k, (d, a.num_kv_heads, a.head_dim), fan_in=d),
        "wv": lecun_init(r_v, (d, a.num_kv_heads, a.head_dim), fan_in=d),
        "wo": lecun_init(
            r_o, (a.num_heads, a.head_dim, d), fan_in=a.num_heads * a.head_dim
        ),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.num_heads, a.head_dim))
        p["bk"] = jnp.zeros((a.num_kv_heads, a.head_dim))
        p["bv"] = jnp.zeros((a.num_kv_heads, a.head_dim))
    return p


def init_kv_cache(cfg, batch: int, length: int, is_global: bool,
                  dtype=jnp.bfloat16):
    """Cache for one layer. Sliding-window layers use a ring buffer of the
    window size; global layers allocate the full length.

    ``pos`` is PER ROW — (batch, length) — so each row advances through its
    ring independently: continuous-batching serving admits/retires rows at
    arbitrary decode steps (serve/cache_pool.py). An entry with pos < 0 is
    invalid and masked out of attention."""
    a = cfg.attention
    if a.sliding_window is not None and not is_global:
        length = min(length, a.sliding_window)
    if a.kind == "mla":
        return {
            "ckv": jnp.zeros((batch, length, a.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, length, a.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, length), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, length, a.num_kv_heads, a.head_dim), dtype),
        "v": jnp.zeros((batch, length, a.num_kv_heads, a.head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def init_paged_kv_cache(cfg, num_blocks: int, block_size: int,
                        dtype=jnp.bfloat16):
    """Paged cache for one layer: one pool of ``num_blocks`` fixed-size
    token blocks shared by ALL rows (serve/block_manager.py hands blocks
    out). Leading dim indexes physical blocks, not batch rows — a request
    reaches its tokens through a per-row block table.

    Unlike the contiguous cache there is no ring: sliding-window layers
    store every position and rely on the window term of `make_mask`
    (paging already bounds memory by tokens actually written, which is
    the job the ring did). Block 0 is reserved as the NULL block — its
    `pos` stays -1 forever, so unallocated table entries gather only
    masked-out keys."""
    a = cfg.attention
    if a.kind == "mla":
        return {
            "ckv": jnp.zeros((num_blocks, block_size, a.kv_lora_rank), dtype),
            "krope": jnp.zeros(
                (num_blocks, block_size, a.qk_rope_head_dim), dtype
            ),
            "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros(
            (num_blocks, block_size, a.num_kv_heads, a.head_dim), dtype
        ),
        "v": jnp.zeros(
            (num_blocks, block_size, a.num_kv_heads, a.head_dim), dtype
        ),
        "pos": jnp.full((num_blocks, block_size), -1, jnp.int32),
    }


def _attend(q, k, v, mask, scale: Optional[float] = None):
    """q: (B,Sq,H,Dk); k: (B,Sk,G,Dk); v: (B,Sk,G,Dv) grouped;
    mask: (B,Sq,Sk) bool or None. Dv may differ from Dk (MLA latent)."""
    b, sq, h, d = q.shape
    g, dv = k.shape[2], v.shape[-1]
    rep = h // g
    scale = scale if scale is not None else 1.0 / float(d) ** 0.5
    qg = q.reshape(b, sq, g, rep, d)
    logits = scale * jnp.einsum(
        "bsgrd,btgd->bgrst", qg.astype(jnp.float32), k.astype(jnp.float32)
    )
    if mask is not None:
        # (B,Sq,Sk) -> (B,1,1,Sq,Sk) to broadcast over (g, rep).
        logits = logits + jnp.where(mask[:, None, None], 0.0, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def _attend_chunked(q, k, v, qpos, kpos, causal: bool,
                    window: Optional[int], scale: Optional[float] = None,
                    is_global=True, block: int = 1024):
    """Flash-style online-softmax attention, scanning KV in blocks — never
    materializes the (Sq, Sk) logits or mask. Used when Sk is long (32k /
    500k shapes); numerically identical to `_attend` (checked in tests)."""
    b, sq, h, d = q.shape
    sk, g, dv = k.shape[1], k.shape[2], v.shape[-1]
    rep = h // g
    scale = scale if scale is not None else 1.0 / float(d) ** 0.5
    if kpos.ndim == 1:  # shared key positions -> per-row
        kpos = jnp.broadcast_to(kpos[None], (b, sk))
    if sk % block != 0:
        pad = (sk + block - 1) // block * block - sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
        sk += pad
    nb = sk // block
    qg = q.astype(jnp.float32).reshape(b, sq, g, rep, d)
    kb = k.astype(jnp.float32).reshape(b, nb, block, g, d).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(b, nb, block, g, dv).transpose(1, 0, 2, 3, 4)
    kpb = kpos.reshape(b, nb, block).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, kp = inp
        s = jnp.einsum("bsgrd,btgd->bgrst", qg, kblk) * scale
        mask = make_mask(qpos, kp, causal, window, is_global)  # (b,sq,block)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bgrst,btgd->bgrsd", p, vblk)
        return (m_new, l, acc), None

    # Without this, autodiff of the scan stores the per-block probability
    # tiles p — the full (Sq × Sk) memory the chunking exists to avoid.
    # Checkpointing the body makes backward recompute p from (q, k-block):
    # the flash-attention backward, expressed through remat.
    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False,
    )

    m0 = jnp.full((b, g, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, g, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, g, rep, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def make_mask(q_positions, k_positions, causal: bool,
              window: Optional[int], is_global=True):
    """Boolean (B?,Sq,Sk) mask: True = attend. Positions may be (S,) or
    (B,S); invalid cache entries carry position -1. `is_global` may be a
    traced scalar bool (gemma3's local:global pattern scanned with shared
    weights): global layers ignore the window."""
    q = q_positions[..., :, None]
    k = k_positions[..., None, :]
    m = k >= 0
    if causal:
        m = m & (k <= q)
    if window is not None:
        m = m & ((k > q - window) | is_global)
    return m


def _ring_slots(cache, positions):
    """Per-row ring addressing shared by every contiguous-cache writer:
    (B?,S) absolute positions -> ((B,S) positions, (B,S) slots). Tokens
    with position < 0 scatter to the out-of-bounds slot `length`, which
    mode="drop" discards — a predicated write with no gather/select."""
    b, length = cache["pos"].shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (b, positions.shape[0]))
    slots = jnp.where(positions >= 0, positions % length, length)  # (B,S)
    return positions, slots


def _paged_address(cache, positions, tables):
    """Block-table addressing shared by every paged-cache writer:
    (B?,S) absolute positions -> ((B,S) positions, (B,S) physical block,
    (B,S) offset). Token at position p of row b lands in physical block
    ``tables[b, p // block_size]`` at offset ``p % block_size``. Tokens
    with position < 0 — and positions whose table entry is still the
    null block — address the out-of-bounds block `num_blocks`, which
    mode="drop" discards (the paged analogue of `_ring_slots`)."""
    nb_total, bs_blk = cache["pos"].shape
    b = tables.shape[0]
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (b, positions.shape[0]))
    logical = jnp.clip(
        jnp.where(positions >= 0, positions // bs_blk, 0),
        0, tables.shape[1] - 1,
    )
    phys = jnp.take_along_axis(tables, logical, axis=1)  # (B,S)
    ok = (positions >= 0) & (phys > 0)
    phys = jnp.where(ok, phys, nb_total)  # OOB -> dropped
    off = jnp.where(ok, positions % bs_blk, 0)
    return positions, phys, off


def _ring_update(cache, new_vals: dict, positions):
    """Write `new_vals[name]` (B,S,...) at per-row ring slots pos % length.

    positions: (S,) shared or (B,S) per row. Tokens with position < 0 are
    NO-OPS — the old cache entry survives. The serving engine relies on
    this twice: (a) inactive/prefilling rows ride through batched decode
    steps with position -1 without corrupting their cache, (b) left-pad
    tokens of a chunked-prefill chunk write nothing."""
    positions, slots = _ring_slots(cache, positions)
    bidx = jnp.arange(slots.shape[0])[:, None]
    out = dict(cache)
    for name, val in new_vals.items():
        out[name] = cache[name].at[bidx, slots].set(
            val.astype(cache[name].dtype), mode="drop"
        )
    out["pos"] = cache["pos"].at[bidx, slots].set(positions, mode="drop")
    return out


def _paged_update(cache, new_vals: dict, positions, tables):
    """Scatter `new_vals[name]` (B,S,...) into the paged pool through the
    per-row block tables (`_paged_address` has the addressing rules;
    inactive rows and left-pad tokens stay exact no-ops)."""
    positions, phys, off = _paged_address(cache, positions, tables)
    out = dict(cache)
    for name, val in new_vals.items():
        out[name] = cache[name].at[phys, off].set(
            val.astype(cache[name].dtype), mode="drop"
        )
    out["pos"] = cache["pos"].at[phys, off].set(positions, mode="drop")
    return out


def _paged_view(cache, tables):
    """Gather a per-row (B, blocks_per_row * block_size, ...) KV view out
    of the paged pool. Entries in logical-position order, so downstream
    masking/attention is identical to the contiguous layout; null-block
    entries carry pos -1 and mask out."""
    b, nb = tables.shape
    bs_blk = cache["pos"].shape[1]
    names = [n for n in cache if n != "pos"]
    vals = [
        cache[n][tables].reshape((b, nb * bs_blk) + cache[n].shape[2:])
        for n in names
    ]
    kpos = cache["pos"][tables].reshape(b, nb * bs_blk)
    return dict(zip(names, vals)), kpos


def reset_block_pos(cache, blocks):
    """Invalidate a fixed-width batch of physical blocks (pos -> -1); pad
    `blocks` with out-of-range ids (mode="drop" discards them). Jit-safe —
    `blocks` is a (W,) traced int array, so alloc-time clears of any count
    run through one compiled program."""
    return dict(cache, pos=cache["pos"].at[blocks].set(-1, mode="drop"))


def copy_kv_blocks(cache, src, dst):
    """Copy physical blocks src[i] -> dst[i] (copy-on-write fork). src/dst
    are (W,) traced int arrays padded with out-of-range ids; padded lanes
    read clamped garbage but scatter out-of-bounds, so they drop."""
    out = dict(cache)
    for name, val in cache.items():
        out[name] = val.at[dst].set(val[src], mode="drop")
    return out


def invalidate_kv_positions(cache, positions):
    """Speculative-decoding rollback for the contiguous ring: pos -> -1 at
    each row's ring slot for `positions` (B, W) absolute positions; lanes
    carrying -1 are no-ops. Rejected draft tokens' K/V entries were
    already unreachable (their positions exceed every future query until
    the row's write frontier overwrites them — causal masking), but
    invalidating them makes the cache state *equal* to never having
    drafted, which the rollback invariant tests check literally. Jit-safe
    fixed-width batch (one compiled signature per verify shape)."""
    _, slots = _ring_slots(cache, positions)
    bidx = jnp.arange(slots.shape[0])[:, None]
    return dict(
        cache, pos=cache["pos"].at[bidx, slots].set(-1, mode="drop")
    )


def invalidate_paged_positions(cache, positions, tables):
    """Paged analogue of `invalidate_kv_positions`: pos -> -1 through the
    block tables for `positions` (B, W); -1 lanes and null-block entries
    drop. Blocks that only held rejected tokens are separately un-reserved
    by BlockManager rollback — this clears rejected entries inside blocks
    the row keeps (the ones sharing a block with accepted tokens)."""
    _, phys, off = _paged_address(cache, positions, tables)
    return dict(cache, pos=cache["pos"].at[phys, off].set(-1, mode="drop"))


def reset_kv_rows(cache, row):
    """Invalidate row(s) of one layer's KV cache: pos -> -1. The stale K/V
    values stay in memory — they are unreachable because make_mask admits
    only entries with pos >= 0, and any later write overwrites both the
    value and its pos. `row` may be a traced scalar (jitted slot clear)."""
    return dict(cache, pos=cache["pos"].at[row].set(-1))


def gqa_apply(params, cfg, x, *, layer_is_global: bool = True,
              positions=None, cache=None, mode: str = "train",
              block_tables=None, paged_kernel: bool = False):
    """Returns (out, new_cache). positions: (S,) shared or (B,S) per-row
    absolute token positions; entries < 0 are pad/inactive (no cache write,
    masked from attention). With ``block_tables`` (B, blocks_per_row) the
    cache is a paged block pool (init_paged_kv_cache) addressed through the
    tables instead of a per-row contiguous ring; ``paged_kernel`` routes
    single-token paged decode through the Pallas kernel that streams pool
    tiles in place (kernels/paged_attention_kernels.py) instead of
    gathering the per-row view — chunked prefill (S > 1) and traced
    ``layer_is_global`` flags keep the gather fallback."""
    a = cfg.attention
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", x, params["wv"].astype(x.dtype))
    if a.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)

    # `layer_is_global` may be traced (scanned local:global pattern), so
    # the window is applied inside the mask rather than branched on here.
    window = a.sliding_window

    if cache is None:
        k_all, v_all, kpos = k, v, positions
    elif block_tables is not None:
        # Paged path: scatter this call's KV through the block tables
        # (write-then-read keeps chunked prefill self-attending, exactly
        # like the ring path below), then attend the pool — in place via
        # the Pallas kernel on the decode hot path, or through the
        # gathered row view (the bit-exact oracle / S>1 fallback). The
        # speculative-decoding verify step (serve/spec_decode.py) is an
        # S = k+1 decode continuation and deliberately takes the gather
        # route: every lane needs its own causal slice of the pool, which
        # is exactly the chunked-prefill contract (a multi-query kernel
        # variant is a recorded follow-up).
        assert mode != "prefill", "paged cache serves chunked prefill only"
        cache = _paged_update(cache, {"k": k, "v": v}, positions,
                              block_tables)
        if (paged_kernel and s == 1
                and not isinstance(layer_is_global, jax.core.Tracer)):
            qpos = (positions[:, 0] if positions.ndim == 2
                    else jnp.broadcast_to(positions[0], (b,)))
            out = paged_decode_attend(
                q[:, 0], cache["k"], cache["v"], cache["pos"],
                block_tables, qpos, causal=cfg.causal, window=window,
                is_global=bool(layer_is_global),
            )[:, None]
            out = jnp.einsum("bshk,hkd->bsd", out,
                             params["wo"].astype(x.dtype))
            return out, cache
        gathered, kpos = _paged_view(cache, block_tables)
        k_all, v_all = gathered["k"], gathered["v"]
    else:
        cache = _ring_update(cache, {"k": k, "v": v}, positions)
        if s > 1 and mode == "prefill":
            # Whole-prompt prefill: attend the input KV directly — the ring
            # buffer may already have wrapped (window < prefill length), so
            # the cache is only valid for *subsequent* decode steps.
            k_all, v_all, kpos = k, v, positions
        else:
            # Decode (s==1) and chunked-prefill continuation (s>1 with
            # mode="decode"): attend over the cache, which now holds both
            # prior chunks and the tokens just written.
            k_all, v_all, kpos = cache["k"], cache["v"], cache["pos"]

    # Flash-style path for long KV: never materializes (Sq, Sk) logits.
    if k_all.shape[1] * max(s, 1) > _CHUNKED_THRESHOLD:
        out = _attend_chunked(q, k_all, v_all, positions, kpos,
                              cfg.causal, window, is_global=layer_is_global)
    else:
        mask = make_mask(positions, kpos, cfg.causal, window,
                         layer_is_global)
        if mask.ndim == 2:  # shared (S,) positions -> add batch dim
            mask = mask[None]
        out = _attend(q, k_all, v_all, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(rng, cfg):
    a = cfg.attention
    d = cfg.d_model
    rs = split_rngs(rng, 6)
    qk_head = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "wq": lecun_init(rs[0], (d, a.num_heads, qk_head), fan_in=d),
        "w_dkv": lecun_init(rs[1], (d, a.kv_lora_rank), fan_in=d),
        "w_krope": lecun_init(rs[2], (d, a.qk_rope_head_dim), fan_in=d),
        "w_uk": lecun_init(
            rs[3], (a.kv_lora_rank, a.num_heads, a.qk_nope_head_dim),
            fan_in=a.kv_lora_rank,
        ),
        "w_uv": lecun_init(
            rs[4], (a.kv_lora_rank, a.num_heads, a.v_head_dim),
            fan_in=a.kv_lora_rank,
        ),
        "wo": lecun_init(
            rs[5], (a.num_heads, a.v_head_dim, d),
            fan_in=a.num_heads * a.v_head_dim,
        ),
    }


def mla_apply(params, cfg, x, *, positions=None, cache=None,
              mode: str = "train", layer_is_global: bool = True,
              block_tables=None, paged_kernel: bool = False):
    """MLA with compressed-KV cache. Decode uses the *absorbed* form:
    q_nope is projected into the latent rank space so attention scores are
    computed against the (B, S, rank) cache directly — no per-step
    re-expansion of K (the production DeepSeek inference trick).

    ``paged_kernel`` is accepted for signature parity but MLA keeps the
    gather fallback: the absorbed decode attends a latent cache whose
    score/value widths differ (rank + rope vs rank), and the latent-pool
    kernel variant is a recorded follow-up (ROADMAP)."""
    del paged_kernel
    a = cfg.attention
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope = q[..., : a.qk_nope_head_dim]
    q_rope = apply_rope(q[..., a.qk_nope_head_dim:], positions, a.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    krope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_krope"].astype(dt))[
            :, :, None
        ],
        positions,
        a.rope_theta,
    )[:, :, 0]

    scale = 1.0 / float(a.qk_nope_head_dim + a.qk_rope_head_dim) ** 0.5

    if cache is not None and block_tables is not None:
        assert mode != "prefill", "paged cache serves chunked prefill only"
        cache = _paged_update(cache, {"ckv": ckv, "krope": krope},
                              positions, block_tables)
        gathered, kpos = _paged_view(cache, block_tables)
        ckv_all, krope_all = gathered["ckv"], gathered["krope"]
    elif cache is not None:
        cache = _ring_update(cache, {"ckv": ckv, "krope": krope}, positions)
        if s > 1 and mode == "prefill":
            # whole-prompt prefill: attend input latents (see gqa_apply)
            ckv_all, krope_all, kpos = ckv, krope, positions
        else:  # decode / chunked-prefill continuation: attend the cache
            ckv_all, krope_all = cache["ckv"], cache["krope"]
            kpos = cache["pos"]
    else:
        ckv_all, krope_all, kpos = ckv, krope, positions

    # Absorbed form: project q_nope into the latent rank space, then MLA is
    # exactly MHA with a single shared KV "head" of dim (rank + rope_dim)
    # for scores and dim rank for values — so it reuses the dense/flash
    # attend paths (and the compressed cache is attended to directly).
    q_lat = jnp.einsum(
        "bshk,rhk->bshr", q_nope.astype(jnp.float32),
        params["w_uk"].astype(jnp.float32),
    ).astype(dt)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)  # (b,s,h,r+rd)
    k_cat = jnp.concatenate([ckv_all, krope_all], axis=-1)[:, :, None]
    v_lat = ckv_all[:, :, None]  # (b,t,1,r)

    if k_cat.shape[1] * max(s, 1) > _CHUNKED_THRESHOLD:
        lat = _attend_chunked(q_cat, k_cat, v_lat, positions, kpos,
                              cfg.causal, None, scale=scale)
    else:
        mask = make_mask(positions, kpos, cfg.causal, None)
        if mask.ndim == 2:
            mask = mask[None]
        lat = _attend(q_cat, k_cat, v_lat, mask, scale=scale)

    # Expand the weighted latent through W_uv once.
    out = jnp.einsum(
        "bshr,rhv->bshv", lat.astype(jnp.float32),
        params["w_uv"].astype(jnp.float32),
    )
    out = jnp.einsum("bshv,hvd->bsd", out.astype(dt), params["wo"].astype(dt))
    return out, cache


def attention_init(rng, cfg):
    return mla_init(rng, cfg) if cfg.attention.kind == "mla" else gqa_init(rng, cfg)


def attention_apply(params, cfg, x, **kw):
    if cfg.attention.kind == "mla":
        return mla_apply(params, cfg, x, **kw)
    return gqa_apply(params, cfg, x, **kw)
