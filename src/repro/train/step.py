"""Jitted train step: value_and_grad + microbatch accumulation + AdamW.

The step is built once per (config, mesh) and jitted with explicit
in/out shardings; gradient accumulation scans over microbatches so the
activation memory is that of ONE microbatch (the standard fit-large-batch
trick); remat inside the model bounds per-layer activations.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..optim import OptimizerConfig, adamw_init, adamw_update


def init_train_state(rng, init_fn, zero1: bool = False):
    """Default (ZeRO-3/FSDP): fp32 params double as the master copy and
    are sharded over (data × model); every use all-gathers them.

    zero1=True (ZeRO-1/2): bf16 compute params replicated over data
    (sharded over model only) + fp32 master/moments sharded over
    (data × model). Trades +params_bf16/data_shards memory for removing
    the per-layer per-pass FSDP all-gathers — at qwen2-72b:train_4k those
    are ~914GB/device/step, 2.4× the roofline compute time."""
    params = init_fn(rng)
    if not zero1:
        return {"params": params, "opt": adamw_init(params)}

    def to_bf16(p):
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(jnp.bfloat16)
        return p

    return {
        "params": jax.tree_util.tree_map(to_bf16, params),
        "master": params,
        "opt": adamw_init(params),
    }


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    microbatches: int = 1):
    """loss_fn(params, batch) -> (loss, metrics). Returns step(state, batch).

    A telemetry-enabled loss (``lm_loss(..., telemetry=True)``) nests the
    model-interior stats pytree under ``metrics["telemetry"]``; it rides
    the same microbatch aggregation below (``max_*`` leaves take the step
    max, the rest the mean) and the Trainer flattens it at log time —
    nothing here special-cases it."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def accumulate(params, batch):
        def reshape(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            loss, metrics, grads = grads_of(params, mb)
            grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zero_grads = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros(()), zero_grads), mbs
        )
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        # Aggregate per-microbatch metrics over the scan axis: max-type
        # inspection stats (e.g. the Soft-MoE `max_combine` softmax-collapse
        # probe) take the step max, everything else the mean — keeping only
        # the last microbatch (the old behavior) under-reports both.
        # Keyed per LEAF path so nested metric pytrees aggregate correctly.
        def agg(path, v):
            leaf = path[-1] if path else None
            name = str(getattr(leaf, "key", getattr(leaf, "name", "")))
            return v.max(axis=0) if name.startswith("max_") else v.mean(axis=0)

        metrics = jax.tree_util.tree_map_with_path(agg, metrics)
        return loss_sum / microbatches, metrics, grads

    def train_step(state, batch):
        if microbatches > 1:
            loss, metrics, grads = accumulate(state["params"], batch)
        else:
            loss, metrics, grads = grads_of(state["params"], batch)
        if "master" in state:  # ZeRO-1: update the sharded fp32 master,
            # then re-broadcast bf16 compute params. The grads->master
            # resharding lowers to a reduce-scatter; the cast-back to the
            # replicated layout lowers to one all-gather per step (vs one
            # per layer per pass under FSDP).
            grads = _match_sharding(grads, state["master"])
            new_master, new_opt, opt_metrics = adamw_update(
                grads, state["opt"], state["master"], opt_cfg
            )
            new_params = jax.tree_util.tree_map(
                lambda m, p: m.astype(p.dtype), new_master, state["params"]
            )
            new_state = {"params": new_params, "master": new_master,
                         "opt": new_opt}
        else:
            new_params, new_opt, opt_metrics = adamw_update(
                grads, state["opt"], state["params"], opt_cfg
            )
            new_state = {"params": new_params, "opt": new_opt}
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["total_loss"] = loss
        return new_state, metrics

    return train_step


def _match_sharding(grads, master):
    """Pin grads to the master's (data×model)-sharded layout — under jit
    the cross-data grad sync then lowers as a reduce-scatter rather than
    an all-reduce (each data shard only needs its slice)."""
    from ..distributed.api import current_mesh
    from ..distributed.sharding import tree_shardings

    mesh = current_mesh()
    if mesh is None:
        return grads
    sh = tree_shardings(mesh, master)
    return jax.tree_util.tree_map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, sh
    )


def jit_train_step(train_step, mesh, state_shardings, batch_shardings):
    """Pin state/batch shardings; donate the state buffer (in-place update
    on device — required to fit two copies of a 72B state)."""
    return jax.jit(
        train_step,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )


def state_shardings(mesh, state, opts=None):
    """Shard optimizer moments exactly like their params (FSDP included).
    ZeRO-1 states ('master' present): compute params shard over model
    only; master + moments keep the full (data × model) sharding."""
    import dataclasses

    from ..distributed.sharding import ShardingOptions, tree_shardings
    from jax.sharding import NamedSharding, PartitionSpec as P

    opts = opts or ShardingOptions()
    if "master" in state:
        compute_sh = tree_shardings(
            mesh, state["params"], dataclasses.replace(opts, fsdp=False)
        )
        master_sh = tree_shardings(mesh, state["master"], opts)
        return {
            "params": compute_sh,
            "master": master_sh,
            "opt": {
                "mu": master_sh,
                "nu": master_sh,
                "step": NamedSharding(mesh, P()),
            },
        }
    param_sh = tree_shardings(mesh, state["params"], opts)
    return {
        "params": param_sh,
        "opt": {
            "mu": param_sh,
            "nu": param_sh,
            "step": NamedSharding(mesh, P()),
        },
    }
