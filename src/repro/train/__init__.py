from .step import (  # noqa: F401
    init_train_state,
    jit_train_step,
    make_train_step,
    state_shardings,
)
from .trainer import StragglerWatchdog, Trainer, TrainerConfig  # noqa: F401
