"""Training loop with production fault-tolerance:

  * checkpoint/restart — CheckpointManager (atomic, async, keep-N); the
    loop always resumes from the latest committed step, and the data
    pipeline is stateless-resumable, so a preempted job replays nothing.
  * preemption handling — SIGTERM/SIGINT trigger a final blocking save
    before exit (the standard TPU-preemption grace-period pattern).
  * straggler watchdog — per-step wall time EWMA; steps slower than
    ``straggler_factor``× the EWMA are logged with their step index (on a
    real fleet this feeds the scheduler to replace the slow host; here it
    records the event and optionally aborts-to-restart).
  * elastic scaling — restore() re-shards onto whatever mesh the restarted
    job has (see CheckpointManager.restore); nothing in the loop assumes
    the device count of the previous incarnation.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax

from ..checkpoint.manager import CheckpointManager
from ..optim import OptimizerConfig
from .step import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_abort: bool = False
    microbatches: int = 1


@dataclass
class StragglerWatchdog:
    factor: float = 3.0
    ewma: Optional[float] = None
    alpha: float = 0.1
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
        # EWMA excludes straggler steps so one hiccup doesn't mask the next
        if not slow:
            self.ewma = dt if self.ewma is None else (
                (1 - self.alpha) * self.ewma + self.alpha * dt
            )
        return slow


class Trainer:
    def __init__(self, cfg: TrainerConfig, loss_fn: Callable,
                 init_fn: Callable, opt_cfg: OptimizerConfig,
                 data, jit_kwargs: Optional[dict] = None):
        self.cfg = cfg
        self.data = data
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep=cfg.keep_checkpoints)
        step_fn = make_train_step(loss_fn, opt_cfg,
                                  microbatches=cfg.microbatches)
        # Donate the train state: the loop reassigns
        # ``state, _ = train_step(state, batch)`` and never reads the old
        # state again, so XLA aliases params/opt moments in place instead
        # of holding two copies across the step (no-op on CPU). Explicit
        # jit_kwargs still override — pass donate_argnums=() to opt out.
        # Proved by the `donation` pass (src/repro/analysis/).
        jit_kwargs = dict(jit_kwargs) if jit_kwargs else {}
        jit_kwargs.setdefault("donate_argnums", (0,))
        self.train_step = jax.jit(step_fn, **jit_kwargs)
        self.init_fn = init_fn
        self.watchdog = StragglerWatchdog(factor=cfg.straggler_factor)
        self._preempted = False
        self.metrics_history: List[dict] = []

    def _install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, rng, start_state: Any = None) -> Any:
        self._install_preemption_hook()
        start_step = 0
        target = (
            start_state if start_state is not None
            else self._abstract_state(rng)
        )
        ckpt_step, ckpt_state = self.ckpt.restore_latest(target)
        if ckpt_step is not None:
            start_step, state = ckpt_step, ckpt_state
            print(f"[trainer] resumed from step {start_step}")
        elif start_state is not None:
            state = start_state
        else:
            state = init_train_state(rng, self.init_fn)

        step = start_step
        while step < self.cfg.total_steps:
            batch = self.data.batch(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["total_loss"])
            dt = time.perf_counter() - t0
            slow = self.watchdog.observe(step, dt)
            if slow:
                print(f"[watchdog] straggler step {step}: {dt:.3f}s "
                      f"(ewma {self.watchdog.ewma:.3f}s)")
                if self.cfg.straggler_abort:
                    self.ckpt.save(step, state, blocking=True)
                    raise RuntimeError("straggler abort -> restart")
            step += 1
            if step % self.cfg.log_every == 0:
                # A telemetry-enabled loss_fn (lm_loss(telemetry=True))
                # nests the model-interior stats pytree under
                # metrics["telemetry"]; flatten it to scalars next to the
                # scalar metrics (serve/telemetry.py owns the naming).
                telem = metrics.pop("telemetry", None)
                m = {k: float(v) for k, v in metrics.items()}
                if telem is not None:
                    from ..serve.telemetry import flatten_telemetry
                    m.update({
                        f"telemetry_{k}": v for k, v in
                        flatten_telemetry(jax.device_get(telem)).items()
                    })
                m["step"] = step
                m["step_time"] = dt
                self.metrics_history.append(m)
                print(f"[trainer] step {step}: loss={m.get('total_loss'):.4f}"
                      f" lr={m.get('lr', 0):.2e} dt={dt:.3f}s")
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save_async(step, state)
            if self._preempted:
                print(f"[trainer] preempted at step {step}; checkpointing")
                self.ckpt.wait()
                self.ckpt.save(step, state, blocking=True)
                return state
        self.ckpt.wait()
        self.ckpt.save(step, state, blocking=True)
        return state

    def _abstract_state(self, rng):
        return jax.eval_shape(
            lambda r: init_train_state(r, self.init_fn), rng
        )
