"""Fault-tolerant checkpointing (no orbax in this environment — built from
scratch on npy + a JSON manifest).

Design points for 1000+ node runs:
  * per-leaf .npy files under a step directory; a manifest.json records the
    flattened tree structure, shapes and dtypes — restore is *elastic*: any
    mesh/device-count can load and reshard (`restore(..., shardings=...)`
    puts each array straight onto its target sharding).
  * atomic commit: writes go to ``step_N.tmp`` then a single rename —
    a crash mid-write never corrupts the latest checkpoint.
  * async: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping the next train steps.
  * keep-N garbage collection.
  * multi-host note: in a real multi-host job each host writes only the
    shards it owns (process-local addressable shards) — here (single
    process) that set is all of them; the manifest format already carries
    per-array metadata so per-host shard files are a strict extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        items[key] = leaf
    return items, jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True):
        items, _ = _flatten(tree)
        # snapshot to host memory (device -> host copy) before async write
        host_items = {
            k: np.asarray(jax.device_get(v)) for k, v in items.items()
        }
        if blocking:
            self._write(step, host_items)
        else:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write_safe, args=(step, host_items), daemon=True
            )
            self._thread.start()

    def save_async(self, step: int, tree: Any):
        self.save(step, tree, blocking=False)

    def _write_safe(self, step, host_items):
        try:
            self._write(step, host_items)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host_items):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "arrays": {}}
        for i, (key, arr) in enumerate(host_items.items()):
            fname = f"arr_{i:06d}.npy"
            to_save = arr
            if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
                # numpy persists ml_dtypes (bfloat16 etc.) as raw void —
                # store the byte view and reconstruct from the manifest.
                to_save = arr.view(np.uint8)
            np.save(os.path.join(tmp, fname), to_save)
            manifest["arrays"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Load into the structure of target_tree. If `shardings` (a pytree
        of NamedSharding matching target_tree) is given, arrays are placed
        directly onto those shardings — elastic restore onto any mesh."""
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        items, _ = _flatten(target_tree)
        sh_items = None
        if shardings is not None:
            sh_items, _ = _flatten(shardings)
        out = {}
        for key, ref in items.items():
            meta = manifest["arrays"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing array {key!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            want_dt = np.dtype(meta["dtype"])
            if arr.dtype != want_dt:
                arr = arr.view(want_dt)  # bfloat16 etc. stored as bytes
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {ref.shape}"
                )
            if sh_items is not None:
                out[key] = jax.device_put(arr, sh_items[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # rebuild the tree in target order
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        leaves = []
        for path, _ in flat:
            key = "/".join(
                str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
            )
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target_tree: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree, shardings)

    # -- gc -----------------------------------------------------------------

    def _gc(self):
        steps = sorted(
            name
            for name in os.listdir(self.directory)
            if name.startswith("step_") and not name.endswith(".tmp")
        )
        for name in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, name))
