"""Error-feedback gradient compression for the cross-pod all-reduce.

At 1000+ nodes the inter-pod (DCN / optical) links are the scarcest
bandwidth; compressing only the `pod`-axis gradient reduce cuts those
bytes 4x (int8) while the intra-pod ICI reduces stay exact.

Scheme: per-tensor symmetric int8 quantization with error feedback — the
quantization residual is carried alongside the optimizer state and added
to the next step's gradient, so the *accumulated* error stays bounded
(contractive-compressor EF analysis, Karimireddy et al. 2019).

``pod_allreduce_compressed`` runs under full-manual ``shard_map`` with the
gradients' own partition specs: each device quantizes its local shard and
only the int8 payload crosses the `pod` axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..distributed.compat import shard_map


def quantize_int8(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g, err):
    """Returns (q, scale, new_err). err is the running residual."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def ef_state_init(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def _reduce_leaf(g, err, axis_name):
    corrected = g.astype(jnp.float32) + err
    # Shared global scale: one scalar pmax (negligible bytes) lets every
    # peer quantize onto the SAME grid, so  sum_i q_i * s  dequantizes the
    # int32 psum exactly up to rounding (≤ s/2 per peer). Only the int8/32
    # payload crosses the slow inter-pod link.
    amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    out = summed.astype(jnp.float32) * scale / n
    return out.astype(g.dtype), new_err


def pod_allreduce_compressed(grads, err_state, mesh, specs):
    """Mean-reduce grads over the `pod` mesh axis with int8 + EF.

    specs: pytree of PartitionSpec matching how grads are sharded over the
    non-pod axes (grads are replicated over `pod` *after* this returns;
    on entry each pod holds its own pod-local gradient).
    """

    @partial(
        shard_map, mesh=mesh, in_specs=(specs, specs),
        out_specs=(specs, specs),
    )
    def run(g, e):
        flat_g, treedef = jax.tree_util.tree_flatten(g)
        flat_e = treedef.flatten_up_to(e)
        outs = [_reduce_leaf(gl, el, "pod") for gl, el in zip(flat_g, flat_e)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]),
        )

    return run(grads, err_state)


def pod_allreduce_mean(grads, mesh, specs):
    """Exact (uncompressed) pod mean-reduce, same shard_map structure —
    the baseline the compression is measured against."""

    @partial(shard_map, mesh=mesh, in_specs=(specs,), out_specs=specs)
    def run(g):
        return jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, "pod"), g)

    return run(grads)
