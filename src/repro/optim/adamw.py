"""AdamW with fp32 master params and global-norm clipping — pure pytrees
(no optax in this environment). Moments inherit the parameter shardings, so
under FSDP they are sharded over (data × model) like the master params.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 1e-3
    schedule: str = "rsqrt"  # "rsqrt" | "cosine" | "linear" | "constant"
    warmup_steps: int = 10_000
    total_steps: int = 300_000
    cooldown_steps: int = 50_000  # paper: linear cooldown tail
    timescale: float = 1e5  # rsqrt timescale (paper App. E)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4
    grad_clip_norm: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)  # noqa: E731
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig,
                 lr=None):
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step) if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def schedule_lr(cfg: OptimizerConfig, step):
    """Paper setup: inverse-sqrt decay with linear warmup and a linear
    cooldown tail (§3.3 / App. E); cosine/linear/constant also provided."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "rsqrt":
        base = jnp.sqrt(cfg.timescale) / jnp.sqrt(jnp.maximum(s, cfg.timescale))
    elif cfg.schedule == "cosine":
        frac = jnp.clip(s / cfg.total_steps, 0.0, 1.0)
        base = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        base = 1.0 - jnp.clip(s / cfg.total_steps, 0.0, 1.0)
    else:
        base = jnp.ones(())
    # linear cooldown tail to zero over the last `cooldown_steps`
    tail = jnp.clip(
        (cfg.total_steps - s) / jnp.maximum(cfg.cooldown_steps, 1), 0.0, 1.0
    )
    return cfg.peak_lr * warm * base * tail
