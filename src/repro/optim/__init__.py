from .adamw import (  # noqa: F401
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    schedule_lr,
)
from .compression import (  # noqa: F401
    compress_with_feedback,
    dequantize_int8,
    ef_state_init,
    pod_allreduce_compressed,
    pod_allreduce_mean,
    quantize_int8,
)
