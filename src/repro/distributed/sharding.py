"""Parameter/activation sharding rules: param-path pattern -> logical spec.

Layout (MaxText-style hybrid):
  * `model` axis — tensor parallelism (attention heads, MLP hidden, vocab)
    and expert parallelism (expert axis of MoE/Soft-MoE stacks, slot axis
    of Phi).
  * `data` axis — data parallelism over the batch, plus FSDP: parameters
    and optimizer moments are additionally sharded over `data` on a
    replicated axis (all-gathered per layer on use). Without FSDP, a 72B
    fp32 master + moments is 18+GB/chip on a 16-wide model axis — over the
    v5e 16GB HBM; with it, ~1.1+2.2GB.
  * `pod` axis — pure data parallelism across pods; only gradient
    all-reduce crosses the inter-pod links.

Rules are regex patterns over the flattened param path. Stacked layer
params (under ``segments``/``enc_segments``) get the leading layer axis
prepended automatically (never sharded — it is scanned over).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .api import logical_to_physical


@dataclass(frozen=True)
class ShardingOptions:
    fsdp: bool = True  # shard params/opt-state over `data` too
    expert_parallel: bool = True  # experts over `model`
    tensor_parallel: bool = True  # heads/ffn over `model`
    # Minimum param size (elements) to bother FSDP-sharding.
    fsdp_min_size: int = 2**16


# (pattern, logical spec) — first match wins. "F" marks the axis that FSDP
# additionally shards with `data` (must currently be None or get data axis).
RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / unembedding: vocab over model, d over data (fsdp)
    (r"(embed|unembed)/table$", ("model", "F")),
    # attention (GQA + MLA q/out)
    (r"attn/wq$", ("F", "model", None)),
    (r"attn/w[kv]$", ("F", "model", None)),
    (r"attn/wo$", ("model", None, "F")),
    (r"attn/b[qkv]$", ("model", None)),
    (r"attn/w_dkv$", ("F", None)),
    (r"attn/w_krope$", ("F", None)),
    (r"attn/w_u[kv]$", ("F", "model", None)),
    (r"cross/wq$", ("F", "model", None)),
    (r"cross/w[kv]$", ("F", "model", None)),
    (r"cross/wo$", ("model", None, "F")),
    # dense MLP: ffn over model
    (r"mlp/w_(gate|up)$", ("F", "model")),
    (r"mlp/w_down$", ("model", "F")),
    # MoE experts: expert axis over model (expert parallelism)
    (r"(moe|mlp)/experts/w_(gate|up)$", ("model", "F", None)),
    (r"(moe|mlp)/experts/w_down$", ("model", "F", None)),
    # shared (always-on) experts: shard their ffn over model instead
    (r"moe/shared/w_(gate|up)$", (None, "F", "model")),
    (r"moe/shared/w_down$", (None, "model", "F")),
    # Soft MoE slot parameters: slots (expert axis) over model
    (r"moe/phi$", ("F", "model", None)),
    (r"moe/scale$", ()),
    (r"moe/router$", ("F", None)),
    # SSM: d_inner (head-aligned) over model
    (r"ssm/w_[zx]$", ("F", "model")),
    (r"ssm/w_[BC]$", ("F", None)),
    (r"ssm/w_dt$", ("F", None)),
    (r"ssm/conv_[wb]$", None),  # packed channel axis: replicate (tiny)
    (r"ssm/(A_log|D|dt_bias)$", None),
    (r"ssm/norm_scale$", ("model",)),
    (r"ssm/w_out$", ("model", "F")),
    # norms / scalars / frontend / vit head
    (r"norm", None),
    (r"frontend/w$", ("F", None)),
    (r"patch_proj/(w|b)$", None),
    (r"pos_emb$", None),
    (r"head/w$", ("F", "model")),
    (r"head/b$", None),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _stacked(path_s: str) -> bool:
    return path_s.startswith(("segments/", "enc_segments/"))


_EXPERT_PAT = re.compile(r"experts/|/phi$|shared/")


def logical_spec_for(path_s: str, ndim: int, shape,
                     opts: ShardingOptions) -> Tuple:
    is_expert = bool(_EXPERT_PAT.search(path_s))
    for pat, spec in RULES:
        if re.search(pat, path_s):
            if spec is None:
                spec = ()
            spec = tuple(spec) + (None,) * (ndim - len(spec))
            out = []
            for ax, name in enumerate(spec[:ndim]):
                if name == "F":
                    name = (
                        "data"
                        if opts.fsdp
                        and _size(shape) >= opts.fsdp_min_size
                        else None
                    )
                if name == "model":
                    enabled = (
                        opts.expert_parallel
                        if is_expert
                        else opts.tensor_parallel
                    )
                    if not enabled:
                        name = None
                out.append(name)
            return tuple(out)
    return (None,) * ndim  # default: replicate


def _size(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def param_specs(params, opts: Optional[ShardingOptions] = None):
    """Pytree of logical specs (tuples of logical axis names) for params."""
    opts = opts or ShardingOptions()

    def one(path, leaf):
        ps = _path_str(path)
        ndim = leaf.ndim
        if _stacked(ps):
            inner = logical_spec_for(ps, ndim - 1, leaf.shape[1:], opts)
            return (None,) + inner
        return logical_spec_for(ps, ndim, leaf.shape, opts)

    return jax.tree_util.tree_map_with_path(one, params)


def to_named_sharding(mesh: Mesh, logical) -> NamedSharding:
    phys = tuple(logical_to_physical(mesh, n) for n in logical)
    return NamedSharding(mesh, P(*phys))


def tree_shardings(mesh: Mesh, params, opts: Optional[ShardingOptions] = None):
    """NamedSharding pytree, honoring divisibility: any axis whose dim is
    not divisible by its mesh-axis size falls back to replicated on that
    axis (correctness over maximal sharding — e.g. 25 heads on 16-way
    model parallelism for hymba)."""
    specs = param_specs(params, opts)

    def one(leaf, logical):
        fixed = []
        for ax, name in enumerate(logical):
            if name is None:
                fixed.append(None)
                continue
            phys = logical_to_physical(mesh, name)
            if phys is None:  # axis disabled (e.g. TP off in pure-DP mode)
                fixed.append(None)
                continue
            size = (
                mesh.shape[phys]
                if isinstance(phys, str)
                else _prod(mesh.shape[a] for a in phys)
            )
            fixed.append(name if leaf.shape[ax] % size == 0 else None)
        return to_named_sharding(mesh, tuple(fixed))

    return jax.tree_util.tree_map(one, params, specs)


def _prod(it):
    n = 1
    for v in it:
        n *= v
    return n


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Inputs: batch over (pod, data); everything else replicated."""
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return NamedSharding(mesh, P(batch_axes, *(None,) * (ndim - 1)))


def abstract_params(init_fn, rng):
    """Shape/dtype pytree of params without allocating (for dry-run)."""
    return jax.eval_shape(init_fn, rng)
