from .api import (  # noqa: F401
    constrain,
    current_mesh,
    logical_to_physical,
    set_mesh,
    spec,
    use_mesh,
)
from .sharding import (  # noqa: F401
    ShardingOptions,
    abstract_params,
    batch_sharding,
    param_specs,
    tree_shardings,
)
