"""Mesh context + activation sharding constraints.

Models call ``constrain(x, "batch", None, "model")`` with *logical* axis
names; the mapping to physical mesh axes lives here, so the same model code
runs on the single-pod (data, model) mesh, the multi-pod (pod, data, model)
mesh, or unsharded on one CPU device (constraints become no-ops).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_batch_over_model(flag: bool) -> None:
    """Pure-DP mode: the logical `batch` axis also spans `model` (tensor
    parallelism off). Used by the perf hillclimb for small models whose
    TP collectives dominate; must match the ShardingOptions used for
    params/inputs or GSPMD will reshard."""
    _state.batch_over_model = flag


def batch_over_model() -> bool:
    return getattr(_state, "batch_over_model", False)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], *, dp_over_model: bool = False):
    prev = current_mesh()
    prev_bom = batch_over_model()
    set_mesh(mesh)
    set_batch_over_model(dp_over_model)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        set_mesh(prev)
        set_batch_over_model(prev_bom)


def logical_to_physical(mesh: Mesh, name: Optional[str]):
    """Logical activation/param axis -> physical mesh axes."""
    if name is None:
        return None
    axes = mesh.axis_names
    if name == "batch":  # data parallel axes (pod x data when multi-pod)
        ba = ("pod", "data") if "pod" in axes else ("data",)
        if batch_over_model():
            ba = ba + ("model",)
        return ba if len(ba) > 1 else ba[0]
    if name == "data":
        return "data"
    if name in ("model", "expert"):  # tensor/expert parallel
        # pure-DP mode: `model` belongs to the batch axes; TP/EP constraints
        # degrade to replicated.
        return None if batch_over_model() else "model"
    if name == "seq":
        # Megatron-style sequence parallelism: the residual stream between
        # layers is sharded over `model` on its sequence axis, so the
        # rematted per-layer activation stash divides by the model axis
        # (an 80-layer 72B stash is 86GB/device replicated, 5.4GB sharded).
        # GSPMD all-gathers at each attention/MLP entry and
        # reduce-scatters after — the AG+RS pair costs what the plain TP
        # all-reduce did. Disabled in pure-DP mode (`model` is then part
        # of the batch axes).
        return None if batch_over_model() else "model"
    raise ValueError(f"unknown logical axis {name!r}")


def spec(*names) -> P:
    """PartitionSpec from logical names, resolved on the current mesh."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*(logical_to_physical(mesh, n) for n in names))


def constrain(x, *names):
    """with_sharding_constraint by logical names; no-op without a mesh.
    Axes whose dimension is not divisible by their mesh-axis size are
    dropped (e.g. the 196-token ViT sequence on a 16-way model axis)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    fixed = []
    for ax, name in enumerate(names):
        if name is None:
            fixed.append(None)
            continue
        phys = logical_to_physical(mesh, name)
        if phys is None:  # e.g. "seq" disabled in pure-DP mode
            fixed.append(None)
            continue
        size = (
            mesh.shape[phys]
            if isinstance(phys, str)
            else _prod(mesh.shape[a] for a in phys)
        )
        fixed.append(phys if x.shape[ax] % size == 0 else None)
    s = NamedSharding(mesh, P(*fixed))
    return jax.lax.with_sharding_constraint(x, s)


def _prod(it):
    n = 1
    for v in it:
        n *= v
    return n


def num_slices(axis: str = "data") -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get(axis, 1)
