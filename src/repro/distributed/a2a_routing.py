"""All-to-all expert-parallel token routing (the §Perf C5 design).

The scatter/gather tokens-choice implementation is GSPMD-hostile under
expert parallelism: the compiler falls back to all-gathering routed
buffers (~791GB/step measured at deepseek-v2-lite:train_4k — EXPERIMENTS
§Perf C0). The production pattern is explicit: each device holds a token
shard, decides expert assignments locally, and exchanges exactly the
routed token payload with the expert-owner devices via all_to_all —
per device ≈ tokens·top_k·d bytes each way per layer, ~100× less.

This module implements that exchange as a shard_map collective with a
fixed per-destination capacity (XLA needs static shapes; overflow tokens
drop exactly like capacity-constrained tokens-choice):

  1. per-device: bucket local tokens by destination device
     (expert_id // experts_per_device) into (devices, cap, d) send
     buffers;
  2. one jax.lax.all_to_all exchanges buffers;
  3. each device applies its LOCAL experts to everything it received;
  4. a second all_to_all returns outputs; combine with gate weights.

Validated on 8 fake devices in tests/test_a2a_routing.py against the
single-device reference. Integration into the pjit train step (partial-
manual shard_map over `model` inside the MoE layer) is the recorded
next step in EXPERIMENTS §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import axis_size, shard_map as _shard_map


def _bucket_by_device(x, expert_idx, gate, num_devices: int,
                      experts_per_device: int, cap: int):
    """x: (t, d) local tokens; expert_idx/gate: (t, k). Returns send
    buffers (devices, cap, d), their (local) expert slots (devices, cap),
    origin token ids (devices, cap) and validity mask."""
    t, d = x.shape
    k = expert_idx.shape[1]
    dest = expert_idx // experts_per_device  # (t, k) device id
    local_e = expert_idx % experts_per_device
    flat_dest = dest.reshape(-1)
    # position of each (token,choice) within its destination bucket
    onehot = jax.nn.one_hot(flat_dest, num_devices, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = (pos * onehot).sum(-1)  # (t*k,)
    keep = pos < cap
    pos_c = jnp.clip(pos, 0, cap - 1)

    send = jnp.zeros((num_devices, cap, d), x.dtype)
    send = send.at[flat_dest, pos_c].add(
        jnp.where(keep[:, None], jnp.repeat(x, k, axis=0), 0)
    )
    send_e = jnp.zeros((num_devices, cap), jnp.int32)
    send_e = send_e.at[flat_dest, pos_c].max(
        jnp.where(keep, local_e.reshape(-1), 0)
    )
    valid = jnp.zeros((num_devices, cap), bool)
    valid = valid.at[flat_dest, pos_c].max(keep)
    return send, send_e, valid, (flat_dest, pos_c, keep)


def a2a_route_and_compute(x, router_w, expert_fn, *, axis_name: str,
                          num_experts: int, top_k: int,
                          capacity_factor: float = 2.0):
    """Runs inside shard_map: x (t_local, d) token shard; router_w (d, E)
    replicated; expert_fn(local_expert_id, tokens) applies THIS device's
    expert. Returns (t_local, d) combined outputs."""
    nd = axis_size(axis_name)
    epd = num_experts // nd
    t, d = x.shape
    cap = max(int(capacity_factor * top_k * t / nd), 1)

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, expert_idx = jax.lax.top_k(probs, top_k)

    send, send_e, valid, (flat_dest, pos_c, keep) = _bucket_by_device(
        x, expert_idx, gate, nd, epd, cap
    )
    # exchange: (devices, cap, d) -> received (devices, cap, d), where
    # axis 0 now indexes the SOURCE device.
    recv = jax.lax.all_to_all(send, axis_name, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis_name, 0, 0, tiled=False)
    recv_v = jax.lax.all_to_all(valid, axis_name, 0, 0, tiled=False)

    # apply local experts: mask per local expert id
    out = jnp.zeros_like(recv, dtype=x.dtype)
    flat = recv.reshape(nd * cap, d)
    fe = recv_e.reshape(-1)
    fv = recv_v.reshape(-1)
    acc = jnp.zeros_like(flat)
    for le in range(epd):
        sel = (fe == le) & fv
        y = expert_fn(le, flat)
        acc = acc + jnp.where(sel[:, None], y, 0)
    out = acc.reshape(nd, cap, d)

    # return trip
    back = jax.lax.all_to_all(out, axis_name, 0, 0, tiled=False)
    # combine: gather each (token, choice)'s output and weight by gate
    flat_out = back[flat_dest, pos_c]  # (t*k, d)
    flat_out = jnp.where(keep[:, None], flat_out, 0)
    gate_n = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    y = (flat_out.reshape(t, top_k, d)
         * gate_n[..., None].astype(flat_out.dtype)).sum(1)
    return y.astype(x.dtype)


def make_a2a_moe(mesh, num_experts: int, top_k: int, d_model: int,
                 capacity_factor: float = 2.0, axis_name: str = "model"):
    """shard_map-wrapped MoE layer: tokens sharded over `axis_name`,
    experts owned by device (expert weights pre-sharded outside)."""

    def fn(x, router_w, expert_gate, expert_up, expert_down):
        # expert_* carry only THIS device's experts: (epd, d, ff) etc.
        def expert_fn(le, toks):
            g = jax.nn.silu(toks @ expert_gate[le].astype(toks.dtype))
            u = toks @ expert_up[le].astype(toks.dtype)
            return (g * u) @ expert_down[le].astype(toks.dtype)

        return a2a_route_and_compute(
            x, router_w, expert_fn, axis_name=axis_name,
            num_experts=num_experts, top_k=top_k,
            capacity_factor=capacity_factor,
        )

    return _shard_map(
        fn, mesh=mesh,
        in_specs=(
            P(axis_name, None),  # tokens sharded
            P(),  # router replicated
            P(axis_name, None, None),  # experts sharded over devices
            P(axis_name, None, None),
            P(axis_name, None, None),
        ),
        out_specs=P(axis_name, None),
    )
