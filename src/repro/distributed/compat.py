"""jax version-compat shims shared across the repo (0.4.x <-> 0.5+).

Three APIs drifted between the jax this container ships (0.4.37) and
newer releases, and each one seeded a tier-1 test failure before it was
shimmed. Every module that needs one imports it from here — the
try/except must never be copy-pasted into call sites again (the seed had
one inline copy in a2a_routing.py while optim/compression.py called
``jax.shard_map`` bare and failed on 0.4.x).

* ``shard_map`` — top-level export on jax >= 0.5, experimental module on
  0.4.x.
* ``axis_size`` — ``jax.lax.axis_size`` is new; ``psum(1, axis)`` is the
  portable spelling (constant-folded, no collective in the compiled
  program).
* ``cost_analysis_dict`` — ``Compiled.cost_analysis()`` returns a dict
  on newer jax but a list of per-module dicts on 0.4.x (and ``None`` on
  some backends); this normalizes all three to a plain dict.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exports shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # 0.4.x: experimental module only
    from jax.experimental.shard_map import shard_map  # noqa: F401


def axis_size(axis_name: str):
    """Size of a named mesh axis, inside shard_map/pmap-traced code."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
