"""Deterministic fault injection + chaos harness for the serving stack.

Every fault is seeded and counter-indexed — a chaos run is a pure
function of its seed, so a failure reproduces exactly. The injector
never reaches into engine internals beyond the public backend surface:
it shadows the backend's bound ``decode``/``verify`` with wrappers on
the *instance* (the class stays untouched), which is exactly where a
real fault would land.

Fault classes (each maps to a defined terminal state — the matrix lives
in docs/serving.md):

* ``poison_logits``      — NaN logits for one slot at model call k
                           -> that row retires, finish_reason="error".
* ``inject_kernel_failure`` — the paged Pallas program raises
                           -> permanent gather-oracle fallback
                           (kernel_fallbacks += 1), serving continues.
* ``hold_blocks``        — pool exhaustion: the injector allocates (and
                           later releases) physical blocks
                           -> admission stalls / live rows preempt.
* ``latency_spike``      — the next n model calls sleep
                           -> deadline misses under load, watchdog
                           exercise.
* ``GarbageDrafter`` / ``FlakyDrafter`` — speculative drafter producing
                           out-of-range junk / raising
                           -> per-row draft disable, output unchanged.
* cancellation storms    — run_chaos cancels random live/queued
                           requests -> finish_reason="cancelled", all
                           resources free within the tick.

``pool_snapshot`` / ``assert_leak_free`` are the invariant checkers the
chaos property test (tests/test_chaos.py) and the CI chaos-smoke job
assert with: after every request reaches a terminal state, the backend
must hold ZERO per-request resources — block pool, refcounts, tables,
slots identical to a fresh engine.

Run standalone (the CI job does)::

    PYTHONPATH=src python -m repro.serve.faults --seed 0 --requests 24
"""
from __future__ import annotations

import random
import time
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .scheduler import QueueFull, Request


# ---------------------------------------------------------------------------
# Drafters that misbehave (speculative-decoding fault surface)
# ---------------------------------------------------------------------------


class GarbageDrafter:
    """Seeded drafter proposing uniform-random token ids, half of them
    OUT of vocab range: exercises draft validation (out-of-range tokens
    must be truncated, never verified) and the zero-acceptance per-row
    disable. Never changes served tokens — garbage drafts just reject."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.rng = random.Random(seed)

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        return [self.rng.randrange(2 * self.vocab_size) for _ in range(k)]


class FlakyDrafter:
    """Drafter that raises on every ``propose`` after the first
    ``ok_calls``: exercises the drafter-exception path (errors counted,
    row's draft lane disabled after ``max_drafter_errors``, serving
    continues non-speculatively for that row)."""

    def __init__(self, ok_calls: int = 0):
        self.ok_calls = ok_calls
        self.calls = 0

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        self.calls += 1
        if self.calls > self.ok_calls:
            raise RuntimeError("injected drafter failure")
        return []


# ---------------------------------------------------------------------------
# Fault injector
# ---------------------------------------------------------------------------


class FaultInjector:
    """Attach seeded faults to one engine's backend.

    ``model_calls`` counts decode+verify model calls since attach; all
    scheduled faults key off it, so timing is deterministic under any
    request interleaving. ``detach()`` restores the pristine backend
    (held blocks must be released first)."""

    def __init__(self, engine, seed: int = 0):
        self.eng = engine
        self.backend = engine.backend
        self.rng = random.Random(seed)
        self.model_calls = 0
        self.nan_injected = 0
        self.kernel_failures = 0
        self.latency_injected = 0
        self._poison: Dict[int, List[int]] = {}  # call index -> slots
        self._latency: Dict[int, float] = {}  # call index -> sleep s
        self._held: List[int] = []  # paged blocks we pinned
        self._held_slots: List[int] = []  # contiguous slots we pinned
        self._orig_decode = self.backend.decode
        self._orig_verify = self.backend.verify
        self.backend.decode = self._wrapped(self._orig_decode)
        self.backend.verify = self._wrapped(self._orig_verify)

    def _wrapped(self, orig):
        def call(params, toks, pos):
            self.model_calls += 1
            sleep_s = self._latency.pop(self.model_calls, 0.0)
            if sleep_s > 0.0:
                self.latency_injected += 1
                time.sleep(sleep_s)
            logits = orig(params, toks, pos)
            for slot in self._poison.pop(self.model_calls, ()):
                # Poison the slot's whole logits row ((B, L, V) for both
                # decode and verify), as a numerically-diverged model
                # would: the engine's finite_rows guard must retire
                # exactly this row with finish_reason="error".
                logits = logits.at[slot].set(jnp.nan)
                self.nan_injected += 1
            return logits

        return call

    def detach(self):
        """Remove the wrappers and release anything still held."""
        self.release_blocks()
        self.backend.decode = self._orig_decode
        self.backend.verify = self._orig_verify

    # -- fault scheduling --------------------------------------------------

    def poison_logits(self, slot: int, after_calls: int = 1):
        """NaN the logits of `slot` on the after_calls-th model call
        from now (decode or verify, whichever lands there)."""
        assert after_calls >= 1
        self._poison.setdefault(self.model_calls + after_calls, []
                                ).append(slot)

    def latency_spike(self, sleep_s: float, after_calls: int = 1):
        """Make the after_calls-th model call from now take `sleep_s`
        longer (deadline/watchdog pressure)."""
        assert after_calls >= 1
        self._latency[self.model_calls + after_calls] = float(sleep_s)

    def inject_kernel_failure(self):
        """Break the paged backend's compiled programs so the NEXT
        decode/verify raises — the backend must fall back to the gather
        oracle permanently and keep serving bit-exactly."""
        be = self.backend
        assert hasattr(be, "_kernel_fallback"), "paged backend only"
        assert be.use_kernel, "kernel already off"

        def _boom(*a, **k):
            raise RuntimeError("injected kernel failure")

        # The fallback path rebuilds _decode/_verify itself, replacing
        # these; nothing to restore.
        be._decode = _boom
        be._verify = _boom
        self.kernel_failures += 1

    def hold_blocks(self, n: Optional[int] = None) -> int:
        """Pool exhaustion: pin `n` free resources (all of them if
        None). Paged: physical blocks via the BlockManager. Contiguous:
        whole slots. Returns how many were actually pinned."""
        be = self.backend
        if hasattr(be, "mgr"):
            free = be.mgr.num_free
            n = free if n is None else min(n, free)
            if n:
                self._held += be.mgr.alloc(n)
            return n
        free = be.pool.num_free
        n = free if n is None else min(n, free)
        for _ in range(n):
            self._held_slots.append(be.pool.acquire())
        return n

    def release_blocks(self):
        """Undo ``hold_blocks`` (refcounts return to pre-fault state)."""
        be = self.backend
        for b in self._held:
            be.mgr.decref(b)
        self._held = []
        for s in self._held_slots:
            be.pool.release(s)
        self._held_slots = []


# ---------------------------------------------------------------------------
# Pool-state invariants
# ---------------------------------------------------------------------------


def pool_snapshot(engine) -> dict:
    """Host-side resource state: everything that must return to its
    fresh-engine value once all work reaches a terminal state."""
    be = engine.backend
    snap = {
        "live_slots": sorted(engine.sched.live.keys()),
        "queued": len(engine.sched.queue),
    }
    if hasattr(be, "mgr"):
        snap.update(
            free_blocks=sorted(be.mgr._free),
            refcounts=be.mgr.ref.tolist(),
            tables=be.tables.copy(),
            free_slots=sorted(be._free_slots),
        )
    else:
        snap["free_slots"] = sorted(be.pool._free)
    if engine._spec is not None:
        snap["spec_pending"] = engine._spec._pending.tolist()
    return snap


def assert_leak_free(engine, flush_prefix_cache: bool = True):
    """Every request reached a terminal state => the engine holds zero
    per-request resources. With ``flush_prefix_cache`` the radix tree is
    evicted first, so the check is exact pool parity with a FRESH
    engine: all blocks free, every refcount zero (null block aside),
    all tables null, no pending speculative state. Without flushing,
    tree-retained blocks are legitimate — each must then be owned by
    exactly the tree (refcount 1)."""
    assert not engine.sched.live, f"live rows leak: {engine.sched.live}"
    assert not engine.sched.queue, "queued requests remain"
    be = engine.backend
    if engine._spec is not None:
        pend = engine._spec._pending
        assert (pend < 0).all(), f"pending spec state leaks: {pend}"
    if not hasattr(be, "mgr"):  # contiguous
        free = sorted(be.pool._free)
        assert free == list(range(be.num_slots)), f"slot leak: {free}"
        return
    assert (be.tables == 0).all(), "block-table entries survive retirement"
    if flush_prefix_cache and be.prefix is not None:
        be.prefix.evict_all_unreferenced(be.mgr)
    if flush_prefix_cache or be.prefix is None:
        assert be.mgr.num_used == 0, (
            f"{be.mgr.num_used} blocks leak (refs "
            f"{np.flatnonzero(be.mgr.ref[1:]) + 1})"
        )
        assert (be.mgr.ref[1:] == 0).all(), "refcount leak"
        assert sorted(be.mgr._free) == list(range(1, be.mgr.num_blocks))
    else:
        # Tree-retained blocks: exactly one owner each (the tree).
        held = np.flatnonzero(be.mgr.ref[1:]) + 1
        assert (be.mgr.ref[held] == 1).all(), (
            f"non-tree refcounts leak: {be.mgr.ref[held]}"
        )


# ---------------------------------------------------------------------------
# Chaos runner
# ---------------------------------------------------------------------------

_TERMINAL = {"eos", "length", "cache_ceiling", "cancelled", "deadline",
             "shed", "error"}


def run_chaos(engine, n_requests: int = 24, seed: int = 0,
              max_steps: int = 3000,
              p_cancel: float = 0.15, p_poison: float = 0.1,
              p_deadline: float = 0.15, p_exhaust: float = 0.05,
              p_latency: float = 0.05,
              kernel_failure: bool = False) -> dict:
    """Drive `engine` through a seeded storm of admissions, client
    cancellations, tiny deadlines, NaN poisonings, pool exhaustion and
    latency spikes, then assert every request landed in a defined
    terminal state and the pool is leak-free. Returns a counter dict.

    Deterministic given (seed, engine config): every decision comes
    from one ``random.Random(seed)``, every fault is counter-indexed.
    """
    rng = random.Random(seed)
    inj = FaultInjector(engine, seed=seed + 1)
    vocab = engine.cfg.vocab_size
    reqs = [
        Request(
            prompt=[rng.randrange(1, vocab) for _ in
                    range(rng.randrange(2, 9))],
            max_new_tokens=rng.randrange(2, 7),
            # Tiny total deadline on a subset: some of these MUST miss.
            deadline_s=(0.0 if rng.random() < p_deadline else None),
        )
        for _ in range(n_requests)
    ]
    pending = list(reqs)
    stats = {"cancel_storms": 0, "exhaustions": 0}
    if kernel_failure and hasattr(engine.backend, "_kernel_fallback"):
        inj.inject_kernel_failure()
    steps = 0
    while (pending or engine.sched.pending()) and steps < max_steps:
        steps += 1
        # Bursty arrivals: 0-3 submissions per tick. A bounded-queue
        # reject is itself a chaos outcome: the request sheds.
        for _ in range(rng.randrange(0, 4)):
            if pending:
                req = pending.pop()
                try:
                    engine.submit(req)
                except QueueFull:
                    req.done = True
                    req.finish_reason = "shed"
                    stats["sheds"] = stats.get("sheds", 0) + 1
                    tracer = getattr(engine, "tracer", None)
                    if tracer is not None:
                        tracer.shed(req)
        if rng.random() < p_cancel:
            victims = ([e.req for e in engine.sched.live.values()]
                       + list(engine.sched.queue))
            if victims:
                engine.cancel(rng.choice(victims))
                stats["cancel_storms"] += 1
        if rng.random() < p_poison and engine.sched.live:
            inj.poison_logits(rng.choice(list(engine.sched.live)))
        if rng.random() < p_latency:
            inj.latency_spike(0.001)
        if rng.random() < p_exhaust and not inj._held:
            if inj.hold_blocks():
                stats["exhaustions"] += 1
        elif inj._held and rng.random() < 0.5:
            inj.release_blocks()
        engine.step()
    inj.release_blocks()
    # A poison scheduled for a call that never happened is not a leak.
    while engine.sched.pending() and steps < 2 * max_steps:
        engine.step()
        steps += 1
    assert not engine.sched.pending(), "chaos run failed to drain"
    for r in reqs:
        assert r.done, "request stranded without a terminal state"
        assert r.finish_reason in _TERMINAL, (
            f"undefined terminal state {r.finish_reason!r}"
        )
    # Observability contract (engines built with trace/flight_recorder):
    # every terminal request's span timeline must be internally
    # consistent with its finish_reason, the tick recorder must actually
    # have recorded, and a forced stall must produce a post-mortem dump.
    tracer = getattr(engine, "tracer", None)
    if tracer is not None:
        from .tracing import validate_timeline
        for r in reqs:
            validate_timeline(r)
        stats["trace_spans"] = tracer.spans_recorded
        stats["timelines_valid"] = len(reqs)
    recorder = getattr(engine, "recorder", None)
    if recorder is not None:
        assert recorder.ticks > 0 and recorder.records(), (
            "flight recorder empty after a chaos run"
        )
        _force_stall_dump(engine, inj)
        assert recorder.dumps >= 1, "forced stall produced no dump"
        assert recorder.last_dump["records"], "stall dump carries no ticks"
        stats["flight_ticks"] = recorder.ticks
        stats["stall_dumps"] = recorder.dumps
    inj.detach()
    assert_leak_free(engine)
    from collections import Counter
    reasons = Counter(r.finish_reason for r in reqs)
    out = dict(stats, steps=steps, nan_injected=inj.nan_injected,
               **{f"finish_{k}": v for k, v in sorted(reasons.items())})
    out.update(engine.robustness_stats())
    return out


def _force_stall_dump(engine, inj: FaultInjector, stall_s: float = 0.02,
                      timeout_s: float = 10.0):
    """Post-chaos stall exercise: pin the whole pool, submit a probe
    request that therefore cannot admit, and spin the tick loop under a
    fast Watchdog whose on_stall dumps the flight recorder — the
    post-mortem path the server wires up, driven synchronously. The
    probe then completes normally once the pool is released (its
    timeline must validate like any other request's)."""
    from .metrics import Watchdog
    recorder = engine.recorder
    wd = Watchdog(
        stall_s=stall_s,
        on_stall=lambda s: recorder.dump("watchdog_stall"),
    )
    probe = Request(prompt=[1, 2, 3], max_new_tokens=2)
    inj.hold_blocks()
    engine.submit(probe)
    deadline = time.perf_counter() + timeout_s
    while wd.stalls == 0 and time.perf_counter() < deadline:
        emitted = engine.step()
        wd.beat(emitted > 0, engine.sched.pending())
    assert wd.stalls >= 1, "stall never fired with the pool pinned"
    inj.release_blocks()
    while engine.sched.pending():
        engine.step()
    assert probe.done and probe.finish_reason in _TERMINAL
    tracer = getattr(engine, "tracer", None)
    if tracer is not None:
        from .tracing import validate_timeline
        validate_timeline(probe)


# ---------------------------------------------------------------------------
# CLI (the CI chaos-smoke job runs this)
# ---------------------------------------------------------------------------


def _main(argv=None):
    import argparse

    import jax

    from ..configs import get_config, reduced
    from ..models import lm_init
    from .engine import ServeEngine
    from .spec_decode import SpecConfig

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--config", default="llama3-8b")
    ap.add_argument("--backend", default="paged",
                    choices=["contiguous", "paged"])
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding + garbage drafter")
    ap.add_argument("--kernel-failure", action="store_true",
                    help="break the Pallas program on the first call")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable span timelines + the flight recorder "
                         "(on by default: the chaos run doubles as the "
                         "observability acceptance check)")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.config))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    kw = {}
    if args.spec:
        kw["spec"] = SpecConfig(
            drafter=GarbageDrafter(cfg.vocab_size, seed=args.seed),
            disable_after_rejects=2,
        )
    if not args.no_trace:
        kw["trace"] = True
        kw["flight_recorder"] = 256
    eng = ServeEngine(
        cfg, params, batch_size=2, max_len=64, backend=args.backend,
        max_queue=8, **kw,
    )
    stats = run_chaos(eng, n_requests=args.requests, seed=args.seed,
                      kernel_failure=args.kernel_failure)
    for k, v in sorted(stats.items()):
        print(f"CHAOS {k}={v}")
    if eng.recorder is not None and eng.recorder.last_dump is not None:
        print("-- flight recorder (last stall dump) --")
        print(eng.recorder.render(
            6, records=eng.recorder.last_dump["records"]))
    print("CHAOS leak_free=1")


if __name__ == "__main__":
    _main()
