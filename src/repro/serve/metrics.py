"""Per-request serving metrics + stuck-step watchdog.

``ServeMetrics`` is a deliberately tiny counter/series surface — pure
host-side python, no jax — shared by the async front end
(serve/server.py), the fault harness (serve/faults.py), and the bench
(benchmarks/bench_serve.py, which exports a snapshot into
``BENCH_serve.json``). Counters are monotonic ints; series collect raw
float observations (queue time, TTFT, total latency) and summarize to
count/mean/p50/p99 at snapshot time.

Canonical counter names (the failure-mode matrix in docs/serving.md maps
each to a finish_reason / degradation):

    submitted, completed, sheds, shed_queue_full, shed_memory,
    shed_retries, cancellations, deadline_misses_ttft,
    deadline_misses_total, errors_nonfinite, preemptions,
    kernel_fallbacks, spec_rows_disabled, spec_drafter_errors,
    watchdog_stalls

``Watchdog`` detects a STUCK engine: work is pending but no token has
been emitted (and no request has terminated) for longer than
``stall_s``. It never kills anything itself — it raises a counter and
invokes an optional callback, leaving policy to the operator. The server
feeds it from its tick loop.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Callable, Dict, List, Optional


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q / 100.0 * len(sorted_vals)))
    return sorted_vals[idx]


class ServeMetrics:
    """Monotonic counters + raw-observation series with a dict snapshot."""

    def __init__(self):
        self.counters: Dict[str, int] = defaultdict(int)
        self.series: Dict[str, List[float]] = defaultdict(list)

    def inc(self, name: str, n: int = 1):
        self.counters[name] += n

    def observe(self, name: str, value: float):
        self.series[name].append(float(value))

    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    def merge_counters(self, other: Dict[str, int]):
        """Adopt externally-owned counters (engine/backend/spec state) by
        OVERWRITE, not add — those objects own their counts; this surface
        just exports them."""
        for k, v in other.items():
            self.counters[k] = int(v)

    def snapshot(self) -> dict:
        out: dict = dict(sorted(self.counters.items()))
        for name, vals in sorted(self.series.items()):
            s = sorted(vals)
            out[name] = {
                "count": len(s),
                "mean": sum(s) / len(s) if s else 0.0,
                "p50": _percentile(s, 50),
                "p99": _percentile(s, 99),
            }
        return out


def collect_engine_metrics(engine, metrics: Optional[ServeMetrics] = None
                           ) -> ServeMetrics:
    """Merge a ServeEngine's robustness counters (preemptions, poisoned-
    row retirements, deadline misses, kernel fallbacks, spec
    degradations) into `metrics` (a fresh surface if None)."""
    m = metrics if metrics is not None else ServeMetrics()
    m.merge_counters(engine.robustness_stats())
    return m


class Watchdog:
    """Stuck-step detection for the serving tick loop.

    `beat(progressed, pending)` is called once per tick: ``progressed``
    means the engine emitted a token or changed request state this tick;
    ``pending`` means there is work that SHOULD be progressing. A stall
    fires when pending work sees no progress for `stall_s` seconds —
    a wedged device call, a scheduler livelock, a fault that ate a row.
    Firing is edge-triggered (once per stall episode, rearmed by the
    next progress) so a genuinely stuck engine does not spam."""

    def __init__(self, stall_s: float = 30.0,
                 on_stall: Optional[Callable[[float], None]] = None):
        assert stall_s > 0
        self.stall_s = stall_s
        self.on_stall = on_stall
        self.stalls = 0
        self._last_progress = time.perf_counter()
        self._armed = True

    def beat(self, progressed: bool, pending: bool) -> bool:
        """Returns True iff a stall fired on this beat."""
        now = time.perf_counter()
        if progressed or not pending:
            self._last_progress = now
            self._armed = True
            return False
        stalled_for = now - self._last_progress
        if self._armed and stalled_for >= self.stall_s:
            self.stalls += 1
            self._armed = False  # edge-triggered: rearm on next progress
            if self.on_stall is not None:
                self.on_stall(stalled_for)
            return True
        return False
