"""Per-request serving metrics + stuck-step watchdog.

``ServeMetrics`` is a deliberately tiny counter/series surface — pure
host-side python, no jax — shared by the async front end
(serve/server.py), the fault harness (serve/faults.py), and the bench
(benchmarks/bench_serve.py, which exports a snapshot into
``BENCH_serve.json``). Counters are monotonic ints; series are
``Histogram``s: Prometheus-style cumulative buckets (what
serve/exporter.py renders as ``_bucket``/``_sum``/``_count``) that ALSO
retain the raw observations, so ``snapshot()`` still summarizes to
exact count/mean/p50/p99.

Canonical counter names (the failure-mode matrix in docs/serving.md maps
each to a finish_reason / degradation; docs/observability.md maps each
to the exported metric name):

    submitted, completed, sheds, shed_queue_full, shed_memory,
    shed_retries, cancellations, deadline_misses_ttft,
    deadline_misses_total, errors_nonfinite, preemptions,
    kernel_fallbacks, spec_rows_disabled, spec_drafter_errors,
    watchdog_stalls

``Watchdog`` detects a STUCK engine: work is pending but no token has
been emitted (and no request has terminated) for longer than
``stall_s``. It never kills anything itself — it raises a counter and
invokes an optional callback with the stall duration (the server's
callback observes the duration as a series and dumps the engine's
flight recorder for a post-mortem), leaving policy to the operator. The
server feeds it from its tick loop.
"""
from __future__ import annotations

import math
import time
from bisect import bisect_left
from collections import defaultdict
from typing import Callable, Dict, List, Optional


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted list: the
    ceil(q/100 * n)-th smallest value (1-indexed)."""
    if not sorted_vals:
        return 0.0
    n = len(sorted_vals)
    idx = max(0, math.ceil(q / 100.0 * n) - 1)
    return sorted_vals[min(idx, n - 1)]


# Latency-oriented bucket bounds (seconds), ~1ms..60s. The exporter adds
# the implicit +Inf bucket.
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Histogram:
    """Cumulative-bucket histogram that retains raw observations.

    ``bucket_counts[i]`` counts observations v with
    ``bounds[i-1] < v <= bounds[i]`` (non-cumulative storage; the
    exporter cumulates at render time per Prometheus ``le`` semantics).
    ``raw`` keeps every observation so snapshot percentiles stay exact —
    series here are per-request latencies, small by construction."""

    __slots__ = ("bounds", "bucket_counts", "sum", "count", "raw")

    def __init__(self, bounds=DEFAULT_BUCKETS):
        assert list(bounds) == sorted(bounds), "bucket bounds must ascend"
        self.bounds = tuple(float(b) for b in bounds)
        self.bucket_counts = [0] * len(self.bounds)
        self.sum = 0.0
        self.count = 0
        self.raw: List[float] = []

    def observe(self, value: float):
        v = float(value)
        self.raw.append(v)
        self.sum += v
        self.count += 1
        i = bisect_left(self.bounds, v)
        if i < len(self.bounds):
            self.bucket_counts[i] += 1
        # else: only the implicit +Inf bucket (== count) covers it

    def cumulative(self) -> List[int]:
        """Cumulative counts per bound (le semantics), excluding +Inf."""
        out, run = [], 0
        for c in self.bucket_counts:
            run += c
            out.append(run)
        return out

    def summary(self) -> dict:
        s = sorted(self.raw)
        return {
            "count": self.count,
            "mean": self.sum / self.count if self.count else 0.0,
            "p50": _percentile(s, 50),
            "p99": _percentile(s, 99),
        }


class ServeMetrics:
    """Monotonic counters + histogram series + last-value gauges, with a
    dict snapshot. Gauges carry optional labels — a (name, labels) pair
    is one series (``set_gauge("program_efficiency", 0.4,
    program="decode")`` and ``program="verify"`` coexist under one
    name), matching how serve/exporter.py renders them."""

    # Suffixes parse_prometheus classifies structurally — a gauge name
    # ending in one would round-trip as the wrong metric kind.
    _RESERVED = ("_total", "_bucket", "_sum", "_count")

    def __init__(self):
        self.counters: Dict[str, int] = defaultdict(int)
        self.series: Dict[str, Histogram] = defaultdict(Histogram)
        # name -> {sorted-label-items tuple -> (labels dict, value)}
        self.gauges: Dict[str, Dict[tuple, tuple]] = defaultdict(dict)

    def inc(self, name: str, n: int = 1):
        self.counters[name] += n

    def observe(self, name: str, value: float):
        self.series[name].observe(value)

    def set_gauge(self, name: str, value: float, **labels):
        assert not name.endswith(self._RESERVED), (
            f"gauge name {name!r} ends in a reserved Prometheus suffix"
        )
        key = tuple(sorted(labels.items()))
        self.gauges[name][key] = (dict(labels), float(value))

    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    def merge_counters(self, other: Dict[str, int]):
        """Adopt externally-owned counters (engine/backend/spec state) by
        OVERWRITE, not add — those objects own their counts; this surface
        just exports them."""
        for k, v in other.items():
            self.counters[k] = int(v)

    def merge_gauges(self, other: Dict[str, float], **labels):
        for k, v in other.items():
            self.set_gauge(k, v, **labels)

    def reset_counters(self):
        """Zero every counter, series and gauge in place (same object —
        references held by servers/benches stay valid). The post-warmup
        reset the benches run before a measured phase, so warmup traffic
        never pollutes the exported numbers."""
        self.counters.clear()
        self.series.clear()
        self.gauges.clear()

    def snapshot(self) -> dict:
        out: dict = dict(sorted(self.counters.items()))
        for name, hist in sorted(self.series.items()):
            out[name] = hist.summary()
        for name, variants in sorted(self.gauges.items()):
            vals = {}
            for _, (labels, value) in sorted(variants.items()):
                key = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                vals[key or "value"] = value
            out[name] = (vals["value"] if list(vals) == ["value"] else vals)
        return out


def collect_engine_metrics(engine, metrics: Optional[ServeMetrics] = None
                           ) -> ServeMetrics:
    """Merge a ServeEngine's robustness counters (preemptions, poisoned-
    row retirements, deadline misses, kernel fallbacks, spec
    degradations) into `metrics` (a fresh surface if None)."""
    m = metrics if metrics is not None else ServeMetrics()
    m.merge_counters(engine.robustness_stats())
    return m


class Watchdog:
    """Stuck-step detection for the serving tick loop.

    `beat(progressed, pending)` is called once per tick: ``progressed``
    means the engine emitted a token or changed request state this tick;
    ``pending`` means there is work that SHOULD be progressing. A stall
    fires when pending work sees no progress for `stall_s` seconds —
    a wedged device call, a scheduler livelock, a fault that ate a row.
    Firing is edge-triggered (once per stall episode, rearmed by the
    next progress) so a genuinely stuck engine does not spam.
    ``on_stall`` receives the stall duration in seconds; the server's
    callback records it as the ``watchdog_stall_s`` series and dumps the
    engine's flight recorder. ``last_stall_s`` keeps the most recent
    duration for introspection."""

    def __init__(self, stall_s: float = 30.0,
                 on_stall: Optional[Callable[[float], None]] = None):
        assert stall_s > 0
        self.stall_s = stall_s
        self.on_stall = on_stall
        self.stalls = 0
        self.last_stall_s = 0.0
        self._last_progress = time.perf_counter()
        self._armed = True

    def beat(self, progressed: bool, pending: bool) -> bool:
        """Returns True iff a stall fired on this beat."""
        now = time.perf_counter()
        if progressed or not pending:
            self._last_progress = now
            self._armed = True
            return False
        stalled_for = now - self._last_progress
        if self._armed and stalled_for >= self.stall_s:
            self.stalls += 1
            self.last_stall_s = stalled_for
            self._armed = False  # edge-triggered: rearm on next progress
            if self.on_stall is not None:
                self.on_stall(stalled_for)
            return True
        return False
