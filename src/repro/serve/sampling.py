"""Batched sampler suite with PER-REQUEST parameters.

One fixed-shape jitted function (`sample_tokens`) samples every pool slot
in parallel; greedy / temperature / top-k / top-p are all expressed as
vectorized masking over the (num_slots, vocab) logits, so a mixed batch
(row 0 greedy, row 1 top-p(0.9), row 2 top-k(5) at temperature 2.0) is one
program — no per-request python dispatch, no recompiles as requests churn.

Randomness is *per request*: row i draws from
``fold_in(PRNGKey(seed_i), step_i)`` where step_i counts that request's
generated tokens. A request therefore reproduces its exact token stream
regardless of which slot it lands in or which other requests share the
batch (tested in tests/test_sampling.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.

    temperature <= 0 means greedy (argmax); top_k <= 0 disables the top-k
    filter; top_p >= 1 disables the nucleus filter. Filters compose
    sequentially (HF-style): logits are temperature-scaled, top-k-masked,
    and the nucleus is computed on the renormalized top-k survivors.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()


def stack_params(params_list) -> dict:
    """Struct-of-arrays view of a list of SamplingParams (host numpy; fed
    straight into `sample_tokens`)."""
    return {
        "temperature": np.array([p.temperature for p in params_list],
                                np.float32),
        "top_k": np.array([p.top_k for p in params_list], np.int32),
        "top_p": np.array([p.top_p for p in params_list], np.float32),
        "seed": np.array([p.seed for p in params_list], np.int32),
    }


def _topk_mask(scaled, top_k):
    """Keep the top_k largest logits per row; top_k<=0 keeps everything."""
    v = scaled.shape[-1]
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    thr = jnp.take_along_axis(sorted_desc, k_eff[:, None] - 1, axis=-1)
    return scaled >= thr  # (B, V)


def _topp_mask(scaled, top_p):
    """Nucleus filter: smallest prefix of descending-prob tokens whose mass
    reaches top_p. `scaled` may already carry -inf from an upstream filter
    (softmax renormalizes over the survivors — sequential composition).
    The top-1 token is always kept; top_p>=1 keeps all."""
    probs = jax.nn.softmax(scaled, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sp = jnp.take_along_axis(probs, order, axis=-1)
    cum_before = jnp.cumsum(sp, axis=-1) - sp  # exclusive cumsum
    keep_sorted = cum_before < top_p[:, None]
    # rank 0 unconditionally: even top_p=0 must leave one sampleable token
    keep_sorted = keep_sorted.at[:, 0].set(True)
    bidx = jnp.arange(scaled.shape[0])[:, None]
    keep = jnp.zeros(scaled.shape, bool).at[bidx, order].set(keep_sorted)
    return keep


def sample_tokens(logits, temperature, top_k, top_p, seed, step):
    """logits: (B, V) f32/bf16; all params (B,). Returns (B,) int32.

    Rows with temperature <= 0 are greedy; the RNG for row i is
    fold_in(PRNGKey(seed_i), step_i) — batch-composition independent.
    """
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    after_k = jnp.where(_topk_mask(scaled, top_k), scaled, -jnp.inf)
    masked = jnp.where(_topp_mask(after_k, top_p), after_k, -jnp.inf)

    keys = jax.vmap(
        lambda s, t: jax.random.fold_in(jax.random.PRNGKey(s), t)
    )(seed, step)
    sampled = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)

    return jnp.where(temperature > 0.0, sampled, greedy_tok)


# --- single-shot convenience wrappers (wave engine / examples / tests) ----


def sample_greedy(rng, logits):
    """logits: (B, 1, V) last-position logits -> (B,) int32."""
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def sample_temperature(rng, logits, temperature: float = 1.0):
    return jax.random.categorical(
        rng, logits[:, -1].astype(jnp.float32) / max(temperature, 1e-6)
    ).astype(jnp.int32)
