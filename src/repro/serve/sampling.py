"""Batched sampler suite with PER-REQUEST parameters.

One fixed-shape jitted function (`sample_tokens`) samples every pool slot
in parallel; greedy / temperature / top-k / top-p are all expressed as
vectorized masking over the (num_slots, vocab) logits, so a mixed batch
(row 0 greedy, row 1 top-p(0.9), row 2 top-k(5) at temperature 2.0) is one
program — no per-request python dispatch, no recompiles as requests churn.

Randomness is *per request*: row i draws from
``fold_in(PRNGKey(seed_i), step_i)`` where step_i counts that request's
generated tokens. A request therefore reproduces its exact token stream
regardless of which slot it lands in or which other requests share the
batch (tested in tests/test_sampling.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.

    temperature <= 0 means greedy (argmax); top_k <= 0 disables the top-k
    filter; top_p >= 1 disables the nucleus filter. Filters compose
    sequentially (HF-style): logits are temperature-scaled, top-k-masked,
    and the nucleus is computed on the renormalized top-k survivors.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()


def stack_params(params_list) -> dict:
    """Struct-of-arrays view of a list of SamplingParams (host numpy; fed
    straight into `sample_tokens`)."""
    return {
        "temperature": np.array([p.temperature for p in params_list],
                                np.float32),
        "top_k": np.array([p.top_k for p in params_list], np.int32),
        "top_p": np.array([p.top_p for p in params_list], np.float32),
        "seed": np.array([p.seed for p in params_list], np.int32),
    }


def _topk_mask(scaled, top_k):
    """Keep the top_k largest logits per row; top_k<=0 keeps everything."""
    v = scaled.shape[-1]
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    thr = jnp.take_along_axis(sorted_desc, k_eff[:, None] - 1, axis=-1)
    return scaled >= thr  # (B, V)


def _topp_mask(scaled, top_p):
    """Nucleus filter: smallest prefix of descending-prob tokens whose mass
    reaches top_p. `scaled` may already carry -inf from an upstream filter
    (softmax renormalizes over the survivors — sequential composition).

    Hardened guarantees (regression-tested in tests/test_sampling.py):
    * The argmax lane survives unconditionally — even when ``top_p`` is
      smaller than the single largest token probability (peaked logits),
      the mask can never go all-False and feed categorical an all--inf
      row. The guarantee is enforced directly on the argmax index, not
      via the sort's rank-0 slot, so it holds under ties and any argsort
      tie-breaking.
    * ``top_p >= 1`` disables the filter exactly: float cumsum drift can
      push an exclusive prefix sum of a long tail above 1.0, which would
      silently mask the tiniest-probability tokens of a nominally
      disabled filter."""
    probs = jax.nn.softmax(scaled, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sp = jnp.take_along_axis(probs, order, axis=-1)
    cum_before = jnp.cumsum(sp, axis=-1) - sp  # exclusive cumsum
    keep_sorted = cum_before < top_p[:, None]
    bidx = jnp.arange(scaled.shape[0])[:, None]
    keep = jnp.zeros(scaled.shape, bool).at[bidx, order].set(keep_sorted)
    # even top_p=0 must leave one sampleable token: pin the argmax lane
    keep = keep.at[bidx[:, 0], jnp.argmax(scaled, axis=-1)].set(True)
    return keep | (top_p[:, None] >= 1.0)


def _filtered_logits(logits, temperature, top_k, top_p):
    """Temperature-scaled logits with the top-k mask and (renormalized)
    nucleus mask applied sequentially — the distribution every sampled row
    draws from. logits: (B, V) f32; params (B,). Returns (B, V) with
    filtered lanes at -inf.

    Non-finite input lanes (NaN / +-inf from a poisoned model step) are
    coerced to -inf BEFORE filtering: NaNs poison every comparison the
    masks are built from, and a single +inf lane makes softmax emit NaNs
    for the whole row. The coercion keeps the masks well-defined; rows
    left without any finite lane are the caller's problem (see
    `guard_support` / `finite_rows`)."""
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    scaled = jnp.where(jnp.isfinite(scaled), scaled, -jnp.inf)
    after_k = jnp.where(_topk_mask(scaled, top_k), scaled, -jnp.inf)
    return jnp.where(_topp_mask(after_k, top_p), after_k, -jnp.inf)


def guard_support(masked):
    """Defense against fully-masked rows: `jax.random.categorical` over an
    all--inf row is UNDEFINED (uniform over NaN weights), and argmax over
    one silently returns lane 0. Returns ``(guarded, support)`` where
    ``support[b]`` is True iff row b kept at least one finite lane, and
    rows without support are replaced by zeros (a uniform, *defined*
    distribution) so the draw can never propagate NaN. Callers must treat
    ``support=False`` rows as poisoned — the engine retires them with
    finish_reason="error" instead of committing their token."""
    support = jnp.isfinite(masked).any(axis=-1)
    return jnp.where(support[..., None], masked, 0.0), support


def finite_rows(logits):
    """(B, ...) -> (B,) bool: True iff every logit of the row is finite.
    The engine's per-tick health check — a False row is poisoned (NaN/inf
    escaped the model) and gets retired with finish_reason="error" before
    its token can corrupt the stream."""
    return jnp.isfinite(logits).all(
        axis=tuple(range(1, logits.ndim))
    )


def sample_tokens(logits, temperature, top_k, top_p, seed, step):
    """logits: (B, V) f32/bf16; all params (B,). Returns (B,) int32.

    Rows with temperature <= 0 are greedy; the RNG for row i is
    fold_in(PRNGKey(seed_i), step_i) — batch-composition independent.
    """
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(
        jnp.where(jnp.isfinite(logits), logits, -jnp.inf), axis=-1
    ).astype(jnp.int32)

    masked, _ = guard_support(
        _filtered_logits(logits, temperature, top_k, top_p)
    )

    keys = jax.vmap(
        lambda s, t: jax.random.fold_in(jax.random.PRNGKey(s), t)
    )(seed, step)
    sampled = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)

    return jnp.where(temperature > 0.0, sampled, greedy_tok)


def sample_tokens_checked(logits, temperature, top_k, top_p, seed, step):
    """`sample_tokens` fused with the per-row health check: returns
    ``(tokens, ok)`` where ``ok[b]`` is False iff row b's raw logits
    carry any non-finite value. Tokens of not-ok rows are defined (the
    support guard makes the draw total) but MEANINGLESS — the engine
    retires those rows with finish_reason="error" and never commits
    them. One jitted program so the guard costs no extra device sync."""
    return (
        sample_tokens(logits, temperature, top_k, top_p, seed, step),
        finite_rows(logits),
    )


# ---------------------------------------------------------------------------
# Speculative decoding: vectorized accept / resample
# ---------------------------------------------------------------------------


def spec_accept_tokens(logits, drafts, n_draft, temperature, top_k, top_p,
                       seed, step):
    """Speculative-decoding accept step against a DETERMINISTIC drafter,
    one jitted fixed-shape program for the whole batch.

    logits: (B, K+1, V) target-model logits from the verify step —
    ``logits[:, j]`` is the next-token distribution after consuming
    verify lane j (lane 0 = the committed pending token, lanes 1..K the
    draft tokens). drafts: (B, K) int32 (drafts[:, j] rides verify lane
    j+1). n_draft: (B,) valid draft count per row. temperature/top_k/
    top_p/seed/step: the per-request sampling suite (identical filtering
    AND identical keys to `sample_tokens`).

    The scheme is exact-match acceptance: lane j's "chain" token is what
    the baseline engine would emit at that position — the argmax for
    greedy rows, ``categorical(fold_in(PRNGKey(seed), step + j),
    filtered_logits)`` for sampled rows (the very same key and masked
    logits `sample_tokens` would use at step+j, so the draw is
    bit-identical). A draft is accepted iff it EQUALS its chain token,
    and the boundary lane emits the chain token itself. For a point-mass
    drafter this accepts with probability q(draft) — the same rate as
    Leviathan rejection sampling — but the emitted token at step s is a
    pure function of (context, seed, s): speculative decoding is
    TOKEN-FOR-TOKEN identical to the non-speculative engine at every
    temperature, burst layout and memory-pressure history (preemption
    replay cannot splice two different streams). Residual-resampling
    would only beat exact-match for a *distributional* draft model —
    recorded as a follow-up alongside the draft-LM drafter.

    Returns ``(n_acc, tokens)``: row b accepts its first ``n_acc[b]``
    drafts and emits ``tokens[b, :n_acc[b]+1]`` (accepted prefix + the
    boundary chain token)."""
    b, k1, v = logits.shape
    k = k1 - 1
    logits = logits.astype(jnp.float32)
    greedy_chain = jnp.argmax(
        jnp.where(jnp.isfinite(logits), logits, -jnp.inf), axis=-1
    ).astype(jnp.int32)  # (B, K+1)

    flat = logits.reshape(b * k1, v)
    masked, _ = guard_support(_filtered_logits(
        flat,
        jnp.repeat(temperature, k1), jnp.repeat(top_k, k1),
        jnp.repeat(top_p, k1),
    ))
    masked = masked.reshape(b, k1, v)

    def row_keys(s, t):
        return jax.vmap(
            lambda j: jax.random.fold_in(jax.random.PRNGKey(s), t + j)
        )(jnp.arange(k1))

    keys = jax.vmap(row_keys)(seed, step)  # (B, K+1) keys
    sampled_chain = jax.vmap(jax.vmap(jax.random.categorical))(
        keys, masked
    ).astype(jnp.int32)
    chain = jnp.where(
        (temperature > 0.0)[:, None], sampled_chain, greedy_chain
    )

    lanes = jnp.arange(k)
    ok = (drafts == chain[:, :k]) & (lanes[None] < n_draft[:, None])
    n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)  # (B,)
    # accepted lanes equal their chain token by construction, so the
    # emitted burst is simply chain[:, :n_acc+1]
    return n_acc.astype(jnp.int32), chain


# --- single-shot convenience wrappers (wave engine / examples / tests) ----


def sample_greedy(rng, logits):
    """logits: (B, 1, V) last-position logits -> (B,) int32."""
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def sample_temperature(rng, logits, temperature: float = 1.0):
    return jax.random.categorical(
        rng, logits[:, -1].astype(jnp.float32) / max(temperature, 1e-6)
    ).astype(jnp.int32)
