"""Host-side consumers of the model-interior telemetry pytree.

The telemetry-variant serving programs (serve/programs.py) emit one
fixed-shape device pytree per call — per-layer block/MoE routing health
plus logit numerics probes (see models/lm.py ``lm_apply``). This module
turns those pytrees into host floats:

* ``flatten_telemetry`` — one device pytree -> flat ``{name: float}``
  scalars (``l<idx>_residual_rms``, ``moe_l<idx>_dispatch_entropy``,
  ``logits_max_abs_logit``, ...). Per-row (B,) leaves reduce by name
  (``max_*`` -> max, nonfinite counts -> sum, else mean). Names never
  end in a Prometheus-reserved suffix (``_total``/``_bucket``/``_sum``/
  ``_count``), so they render directly as gauges.
* ``telemetry_rows`` — the per-row view ``{layer: {stat: (B,) array}}``
  the batch-variance probe compares slot-by-slot.
* ``TelemetryAggregator`` — drains a backend's ``last_telemetry``
  stash once per engine phase; keeps the latest flat stats per phase
  (``prefill`` / ``decode`` / ``verify``) and the per-tick delta the
  flight recorder stores.
* ``batch_variance_probe`` — serves the same request alone vs
  co-batched and reports the target row's per-step routing-stat
  divergence (ROADMAP "batch-invariant MoE serving" acceptance
  instrument; semantics in docs/observability.md).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

_RESERVED_SUFFIXES = ("_total", "_bucket", "_sum", "_count")


def _gauge_safe(name: str) -> str:
    """Keep flat stat names out of the Prometheus parser's reserved
    suffix space (exporter.parse_prometheus classifies by suffix):
    ``nonfinite_count`` -> ``nonfinite_count_val``."""
    if name.endswith(_RESERVED_SUFFIXES):
        return name + "_val"
    return name


def _reduce(name: str, arr: np.ndarray) -> float:
    """Reduce a per-row leaf to one scalar by stat semantics."""
    if arr.ndim == 0:
        return float(arr)
    if name.startswith("max_"):
        return float(arr.max())
    if "nonfinite" in name:
        return float(arr.sum())
    return float(arr.mean())


def flatten_telemetry(tree) -> Dict[str, float]:
    """Serving-path (unrolled) telemetry pytree -> flat host scalars.

    ``tree`` is the host copy of ``lm_apply``'s telemetry output:
    ``{"layers": {idx: {stat: scalar, "moe": {...}}}, "logits": {...}}``.
    The per-row ``rows`` subtrees are skipped here (see
    ``telemetry_rows``)."""
    flat: Dict[str, float] = {}
    for idx, layer in sorted(tree.get("layers", {}).items()):
        for k, v in layer.items():
            if k == "moe":
                for mk, mv in v.items():
                    if mk == "rows":
                        continue
                    flat[_gauge_safe(f"moe_l{idx}_{mk}")] = _reduce(
                        mk, np.asarray(mv))
            else:
                flat[_gauge_safe(f"l{idx}_{k}")] = _reduce(k, np.asarray(v))
    for k, v in tree.get("logits", {}).items():
        flat[_gauge_safe(f"logits_{k}")] = _reduce(k, np.asarray(v))
    return flat


def telemetry_rows(tree) -> Dict[object, Dict[str, np.ndarray]]:
    """Per-row view: ``{layer_idx: {stat: (B,)}}`` for every MoE layer
    that emitted a ``rows`` subtree, plus ``{"logits": {stat: (B,)}}``."""
    out: Dict[object, Dict[str, np.ndarray]] = {}
    for idx, layer in tree.get("layers", {}).items():
        rows = layer.get("moe", {}).get("rows")
        if rows:
            out[idx] = {k: np.asarray(v) for k, v in rows.items()}
    logits = tree.get("logits")
    if logits:
        out["logits"] = {k: np.asarray(v) for k, v in logits.items()}
    return out


class TelemetryAggregator:
    """Pulls ``(phase, device pytree)`` stashes off a backend and keeps
    the latest host-side stats per phase. One ``jax.device_get`` per
    drained phase — the telemetry pytree is a few hundred scalars, so
    the sync is the cost of turning the feature on, never of having it
    compiled in."""

    def __init__(self):
        self.latest: Dict[str, Dict[str, float]] = {}
        self.latest_rows: Dict[str, dict] = {}
        self.tick: Dict[str, Dict[str, float]] = {}
        self.drained = 0

    def begin_tick(self):
        self.tick = {}

    def drain(self, backend) -> Optional[str]:
        """Consume the backend's stash (if any); returns the phase."""
        stash = getattr(backend, "last_telemetry", None)
        if stash is None:
            return None
        backend.last_telemetry = None
        phase, tree = stash
        host = jax.device_get(tree)
        flat = flatten_telemetry(host)
        self.latest[phase] = flat
        self.latest_rows[phase] = telemetry_rows(host)
        self.tick[phase] = flat
        self.drained += 1
        return phase

    def gauges(self) -> Dict[str, float]:
        """Prometheus-ready gauge names: ``moe_<phase>_l<idx>_<stat>``
        for MoE routing health, ``model_<phase>_<stat>`` for the rest."""
        out: Dict[str, float] = {}
        for phase, flat in self.latest.items():
            for k, v in flat.items():
                if k.startswith("moe_"):
                    out[f"moe_{phase}_{k[len('moe_'):]}"] = v
                else:
                    out[f"model_{phase}_{k}"] = v
        return out


# ---------------------------------------------------------------------------
# Batch-variance probe
# ---------------------------------------------------------------------------


def _collect_target_steps(engine, target, fillers,
                          max_ticks: int = 2000) -> List[dict]:
    """Drive the engine to completion, recording the target row's
    per-step decode telemetry: one ``{"layer:stat": value}`` dict per
    decode call the target participated in."""
    for req in [target] + fillers:
        engine.submit(req)
    steps: List[dict] = []
    for _ in range(max_ticks):
        if target.done and not engine.sched.pending():
            break
        entry = engine.sched.entry_for(target)
        in_decode = entry is not None and entry in engine.sched.decode_entries()
        slot = entry.slot if entry is not None else None
        engine.step()
        # record only ticks whose decode call actually advanced the
        # target row: it was a decode entry before the tick and did not
        # retire during it (the retirement tick's decode excludes it)
        if in_decode and not target.done and "decode" in engine.telemetry.tick:
            rows = engine.telemetry.latest_rows.get("decode", {})
            rec = {}
            for layer, stats in rows.items():
                for k, v in stats.items():
                    if np.ndim(v) >= 1 and np.shape(v)[0] > slot:
                        rec[f"{layer}:{k}"] = float(np.asarray(v)[slot])
            steps.append(rec)
    return steps


def batch_variance_probe(cfg, params, prompt, batch_size: int = 4,
                         max_new_tokens: int = 8, max_len: int = 64,
                         backend: str = "contiguous",
                         **engine_kw) -> dict:
    """Quantify batch-composition dependence of the serving forward pass.

    Serves ``prompt`` twice with telemetry on: alone (batch_size=1) and
    co-batched with ``batch_size - 1`` distinct filler requests, then
    compares the TARGET row's per-decode-step telemetry (per-layer MoE
    routing rows + per-row logit probes) step-by-step between the runs.

    Returns ``{"divergence", "per_stat", "steps_compared"}`` where
    ``divergence`` is the max absolute per-step difference over all
    stats. Serving routes every arch per-row — dense MLPs, Soft MoE's
    per-sequence softmaxes, and (since the batch-invariant refactor) the
    sparse variants too, which drop their group/capacity competition at
    serving and route each row's tokens droplessly — so the probe must
    read ~0 (< 1e-5) on EVERY served arch, group-routed BPR
    tokens-choice with binding capacity included. A finite reading on a
    default config is a regression. The only sanctioned way to make it
    read finite is the ``MoEConfig.batch_coupled=True`` escape hatch
    (old training-time group routing at serving) with
    ``group_size = batch_size``, a ``capacity_factor`` low enough that
    buffers bind, and ``bpr=True`` (positional priority always favors
    the target in row 0; batch priority re-ranks by router confidence
    across the group, so fillers can evict the target — the paper's
    §3.5 batch effect); CI and the bench run exactly that configuration
    to prove the instrument itself is still alive. This is the
    measurement side of the ROADMAP "batch-invariant MoE serving" item.
    """
    from .engine import ServeEngine
    from .scheduler import Request

    def run(n_rows: int, fillers: List[list]) -> List[dict]:
        eng = ServeEngine(cfg, params, batch_size=n_rows, max_len=max_len,
                          backend=backend, telemetry=True, **engine_kw)
        tgt = Request(prompt=list(prompt), max_new_tokens=max_new_tokens)
        fil = [Request(prompt=list(f), max_new_tokens=max_new_tokens)
               for f in fillers]
        return _collect_target_steps(eng, tgt, fil)

    vocab = cfg.vocab_size
    fillers = [[(t * (i + 2) + 1) % vocab for t in prompt]
               for i in range(batch_size - 1)]
    solo = run(1, [])
    cob = run(batch_size, fillers)

    per_stat: Dict[str, float] = {}
    n = min(len(solo), len(cob))
    for a, b in zip(solo[:n], cob[:n]):
        for k in a.keys() & b.keys():
            d = abs(a[k] - b[k])
            if np.isfinite(d):
                per_stat[k] = max(per_stat.get(k, 0.0), d)
    return {
        "divergence": max(per_stat.values(), default=0.0),
        "per_stat": dict(sorted(per_stat.items())),
        "steps_compared": n,
    }
