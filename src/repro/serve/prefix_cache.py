"""Radix-tree prefix cache over paged KV blocks.

Maps token-id prefixes to chains of physical cache blocks so a request
whose prompt starts with an already-served prefix (the shared system
prompt case) skips prefill for the shared part: admission walks the tree,
pins the matched chain into the new request's block table, and prefill
starts at the first uncached token.

Structure and invariants (tested in tests/test_prefix_cache.py):

* One node per FULL block: the edge key is the block's exact
  ``block_size``-token id tuple. Partial blocks are never cached — a
  cached block is immutable prompt history, fully written, and is never
  written again by anyone (writers go through copy-on-write; the engine
  never targets positions inside a matched chain).
* Each node holds one reference on its physical block (BlockManager
  refcount). A matched request adds its own reference, so an in-use
  block's refcount is >= 2 and eviction (which only touches refcount-1
  blocks) can never free memory under a live request.
* ``match`` is capped at the prompt's last-but-one token: at least one
  prompt token always re-runs, because the engine needs the model's
  next-token logits for the final prompt position.
* Eviction is LRU over LEAVES only (a node's children always carry
  last_use >= their parent's from the same walk, so chains evict
  tail-first and the tree never dangles). ``last_use`` is a logical
  counter, not wall-clock — deterministic under test.
* Insertion dedups: if a node for the same token block already exists,
  the incumbent block is kept and the newcomer's duplicate is NOT
  adopted (it stays owned by its request alone and frees at retirement).

Eviction cost: candidate leaves live in a lazy min-heap keyed by the
logical clock, so ``evict_one`` is O(log n) amortized — it pops the true
LRU leaf without rescanning the tree (the seed implementation walked
every node per evicted block, O(tree) under memory pressure). The heap
is *lazy*: touching a node (match / insert dedup) pushes a fresh entry
rather than reordering, and stale entries — node evicted, no longer a
leaf, or carrying an outdated clock — are discarded when popped. Pinned
leaves (block refcount > 1: a live request or a fork also holds the
block) are re-pushed after the scan, since the tree is not told when the
BlockManager refcount drops back to 1; the pinned set is bounded by live
requests, so the amortized bound stands. Invariant: every evictable leaf
always has at least one heap entry carrying its current ``last_use``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple


class _Node:
    __slots__ = ("key", "block", "children", "parent", "last_use")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_use = 0


class RadixPrefixCache:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _Node((), 0, None)  # sentinel; never evicted
        self._clock = 0
        self.hits = 0  # blocks served from cache (stats for the bench)
        self.misses = 0  # lookups that matched nothing
        # Lazy LRU heap of (last_use, seq, node) eviction candidates; seq
        # breaks clock ties FIFO and keeps node comparison out of heapq.
        self._lru: List[Tuple[int, int, _Node]] = []
        self._seq = 0
        self._n_nodes = 0  # live tree nodes (cheap len for compaction)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _push_lru(self, node: "_Node"):
        """Register `node` as an eviction candidate at its current clock.
        Call whenever a node is a leaf and its last_use just changed (or
        it just became a leaf); earlier heap entries go stale and are
        skipped at pop time. When stale entries dominate (a long run of
        hits with no memory pressure pushes one per admission), the heap
        is rebuilt from the live leaves — O(tree), amortized away by the
        pushes that grew it."""
        if node is self.root or node.children:
            return
        self._seq += 1
        heapq.heappush(self._lru, (node.last_use, self._seq, node))
        if len(self._lru) > max(64, 4 * self._n_nodes):
            self._compact_lru()

    def _compact_lru(self):
        entries = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            for child in n.children.values():
                if child.children:
                    stack.append(child)
                else:
                    self._seq += 1
                    entries.append((child.last_use, self._seq, child))
        self._lru = entries
        heapq.heapify(self._lru)

    def __len__(self) -> int:
        n = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    # -- lookup ------------------------------------------------------------

    def match(self, tokens: List[int]) -> List[int]:
        """Longest cached chain of full blocks covering a strict prefix of
        ``tokens[:-1]`` (see module invariants). Returns the physical
        block ids in logical order and LRU-touches the path. The CALLER
        increfs the returned blocks (BlockManager) before using them, and
        calls ``record_lookup`` once the request actually admits — a
        queue-blocked request re-matches every admission attempt, and
        those retries must not inflate the hit stats."""
        bs = self.block_size
        limit = (len(tokens) - 1) // bs  # last token never cached-matched
        now = self._tick()
        node = self.root
        out: List[int] = []
        for i in range(limit):
            key = tuple(tokens[i * bs: (i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = now
            out.append(child.block)
            node = child
        # Only the deepest matched node can be a leaf (every other node on
        # the path has the next node as a child); refresh its LRU entry.
        self._push_lru(node)
        return out

    def record_lookup(self, n_blocks: int):
        """Account one ADMITTED request's match result: `n_blocks` blocks
        served from cache (0 = cold lookup)."""
        if n_blocks:
            self.hits += n_blocks
        else:
            self.misses += 1

    # -- insertion ---------------------------------------------------------

    def insert(self, tokens: List[int], blocks: List[int], mgr) -> int:
        """Register a fully-prefilled chain: tokens must be a whole number
        of blocks and ``blocks[i]`` the physical block holding block i's
        KV. New nodes take one reference on their block via ``mgr``;
        existing nodes keep their incumbent block (dedup). Returns the
        number of newly adopted blocks."""
        bs = self.block_size
        assert len(tokens) == len(blocks) * bs, "insert wants full blocks"
        now = self._tick()
        node = self.root
        adopted = 0
        for i, block in enumerate(blocks):
            key = tuple(tokens[i * bs: (i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                mgr.incref(block)
                child = _Node(key, block, node)
                node.children[key] = child
                adopted += 1
                self._n_nodes += 1
            child.last_use = now
            node = child
        # The chain tail is the only possible leaf of this walk; nodes
        # that just gained a child leave stale heap entries behind, which
        # evict_one discards on pop.
        self._push_lru(node)
        return adopted

    # -- eviction ----------------------------------------------------------

    def evict_one(self, mgr) -> bool:
        """Drop the least-recently-used UNREFERENCED leaf (block refcount
        1 means only the tree holds it) and release its block. Returns
        False when nothing is evictable — every cached block is pinned by
        a live request.

        O(log n) amortized: pops the lazy LRU heap instead of rescanning
        the tree. Stale entries (node evicted, grew children, or touched
        since push) are discarded; pinned leaves are set aside and
        re-pushed — refcounts change outside the tree's sight, so their
        entries must survive until the pin drops."""
        pinned: List[Tuple[int, int, _Node]] = []
        victim: Optional[_Node] = None
        while self._lru:
            lu, seq, node = heapq.heappop(self._lru)
            parent = node.parent
            if (parent is None or parent.children.get(node.key) is not node
                    or node.children or lu != node.last_use):
                continue  # stale — a fresher entry (or none) supersedes it
            if mgr.ref[node.block] != 1:
                pinned.append((lu, seq, node))
                continue
            victim = node
            break
        for entry in pinned:
            heapq.heappush(self._lru, entry)
        if victim is None:
            return False
        parent = victim.parent
        del parent.children[victim.key]
        victim.parent = None  # mark detached for any remaining heap entry
        self._n_nodes -= 1
        mgr.decref(victim.block)
        if not parent.children:
            # chain tail removed: the parent is the next LRU candidate
            self._push_lru(parent)
        return True

    def evict_all_unreferenced(self, mgr) -> int:
        """Flush every evictable node (shutdown / tests)."""
        n = 0
        while self.evict_one(mgr):
            n += 1
        return n
