"""Prometheus text-format exporter for the serving metrics surface.

``render_prometheus`` turns one ``ServeMetrics`` (serve/metrics.py)
into the text exposition format (version 0.0.4): counters as
``<ns>_<name>_total``, histogram series as cumulative
``<ns>_<name>_bucket{le="..."}`` plus ``_sum``/``_count``, last-value
gauges (plain or labeled — the model-interior telemetry surface:
``<ns>_moe_*`` / ``<ns>_model_*`` routing-health and numerics stats,
``<ns>_program_efficiency{program="..."}``), and an
optional frozen engine-config info gauge
``<ns>_engine_info{arch="...",...} 1`` (the Prometheus idiom for
exposing build/config constants as labels). ``AsyncServer`` serves it
at ``/metrics``; ``bench_serve.py`` snapshots the same text into its
history rows.

``parse_prometheus`` is the strict round-trip validator the tests and
the CI ``metrics-smoke`` job scrape with: every line must match the
exposition grammar, histogram buckets must be cumulative
(non-decreasing, ``+Inf`` == ``_count``), and the structured result
must reproduce the counters/histograms that were rendered.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from .metrics import ServeMetrics

NAMESPACE = "repro_serve"
CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABEL = rf'{_NAME}="(?:[^"\\\n]|\\\\|\\"|\\n)*"'
_VALUE = r"(?:[+-]?(?:\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)|[+-]?Inf|NaN)"
_COMMENT_RE = re.compile(
    rf"^# (?:HELP {_NAME} [^\n]*|TYPE {_NAME} (?:counter|gauge|histogram|"
    rf"summary|untyped))$"
)
_SAMPLE_RE = re.compile(
    rf"^({_NAME})(\{{{_LABEL}(?:,{_LABEL})*\}})? ({_VALUE})$"
)
_LABEL_RE = re.compile(rf'({_NAME})="((?:[^"\\\n]|\\\\|\\"|\\n)*)"')


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _unescape_label(v: str) -> str:
    return (v.replace("\\n", "\n").replace('\\"', '"')
            .replace("\\\\", "\\"))


def _fmt(v: float) -> str:
    """Shortest float form Prometheus accepts (no trailing zeros)."""
    return format(float(v), ".12g")


def render_prometheus(metrics: ServeMetrics,
                      info: Optional[Dict[str, object]] = None,
                      namespace: str = NAMESPACE) -> str:
    """Text exposition of `metrics` (+ an optional engine-info gauge)."""
    lines = []
    for name, val in sorted(metrics.counters.items()):
        # Counter convention: one `_total` suffix (some counters, e.g.
        # deadline_misses_total, already carry it — don't double up).
        full = f"{namespace}_{name}"
        if not full.endswith("_total"):
            full += "_total"
        lines.append(f"# TYPE {full} counter")
        lines.append(f"{full} {int(val)}")
    for name, hist in sorted(metrics.series.items()):
        full = f"{namespace}_{name}"
        lines.append(f"# TYPE {full} histogram")
        for bound, cum in zip(hist.bounds, hist.cumulative()):
            lines.append(f'{full}_bucket{{le="{_fmt(bound)}"}} {cum}')
        lines.append(f'{full}_bucket{{le="+Inf"}} {hist.count}')
        lines.append(f"{full}_sum {_fmt(hist.sum)}")
        lines.append(f"{full}_count {hist.count}")
    for name, variants in sorted(metrics.gauges.items()):
        full = f"{namespace}_{name}"
        lines.append(f"# TYPE {full} gauge")
        for _, (labels, value) in sorted(variants.items()):
            if labels:
                labelstr = ",".join(
                    f'{k}="{_escape_label(v)}"'
                    for k, v in sorted(labels.items())
                )
                lines.append(f"{full}{{{labelstr}}} {_fmt(value)}")
            else:
                lines.append(f"{full} {_fmt(value)}")
    if info:
        full = f"{namespace}_engine_info"
        labels = ",".join(
            f'{k}="{_escape_label(v)}"' for k, v in sorted(info.items())
        )
        lines.append(f"# TYPE {full} gauge")
        lines.append(f"{full}{{{labels}}} 1")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Strictly parse + validate exposition text.

    Returns ``{"counters": {name: int}, "histograms": {name:
    {"buckets": [(le, cum), ...], "sum": float, "count": int}},
    "gauges": {name: (labels_dict, value)}}`` with the namespace prefix
    kept. Raises ``ValueError`` on any malformed line and
    ``AssertionError`` on broken histogram invariants."""
    counters: Dict[str, int] = {}
    gauges: Dict[str, tuple] = {}
    raw: Dict[str, dict] = {}  # histogram name -> parts
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line:
            raise ValueError(f"line {lineno}: empty line inside body")
        if line.startswith("#"):
            if not _COMMENT_RE.match(line):
                raise ValueError(f"line {lineno}: bad comment {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: bad sample {line!r}")
        name, labelstr, value = m.group(1), m.group(2), m.group(3)
        labels = dict(
            (k, _unescape_label(v))
            for k, v in _LABEL_RE.findall(labelstr or "")
        )
        if name.endswith("_total"):
            counters[name] = int(float(value))
        elif name.endswith("_bucket"):
            base = name[: -len("_bucket")]
            le = labels.get("le")
            if le is None:
                raise ValueError(f"line {lineno}: bucket without le")
            bound = float("inf") if le == "+Inf" else float(le)
            raw.setdefault(base, {"buckets": []})["buckets"].append(
                (bound, int(float(value)))
            )
        elif name.endswith("_sum"):
            raw.setdefault(name[: -len("_sum")], {"buckets": []}
                           )["sum"] = float(value)
        elif name.endswith("_count"):
            raw.setdefault(name[: -len("_count")], {"buckets": []}
                           )["count"] = int(float(value))
        else:
            gauges[name] = (labels, float(value))
    histograms: Dict[str, dict] = {}
    for name, parts in raw.items():
        buckets = parts.get("buckets", [])
        assert buckets, f"{name}: histogram without buckets"
        assert "sum" in parts and "count" in parts, (
            f"{name}: histogram missing _sum/_count"
        )
        bounds = [b for b, _ in buckets]
        assert bounds == sorted(bounds), f"{name}: bucket order broken"
        cums = [c for _, c in buckets]
        assert cums == sorted(cums), (
            f"{name}: bucket counts not cumulative: {cums}"
        )
        assert bounds[-1] == float("inf"), f"{name}: missing +Inf bucket"
        assert cums[-1] == parts["count"], (
            f"{name}: +Inf bucket {cums[-1]} != count {parts['count']}"
        )
        histograms[name] = {
            "buckets": buckets,
            "sum": parts["sum"],
            "count": parts["count"],
        }
    return {"counters": counters, "histograms": histograms,
            "gauges": gauges}
