"""Continuous-batching scheduler: admission queue + per-slot state machine.

Pure host-side bookkeeping — no jax in this module — so the policy is unit
testable without a model. The engine (engine.py) owns the device work and
drives one `Scheduler` through ticks:

    FREE --admit/bind--> PREFILL --last chunk--> DECODE --EOS/len--> FREE

* Admission is FIFO. A request is bound to a cache-pool slot the moment one
  is free; its prompt is then fed in fixed-size chunks (one chunk per engine
  tick, interleaved with decode steps so running requests keep streaming
  while a long prompt loads).
* Chunks are RIGHT-ALIGNED: the first chunk is left-padded with position -1
  tokens (exact no-ops at every layer), so every chunk is shape (1, C), the
  last real token always sits at index C-1, and chunk count is the only
  per-request variable — shapes never change, nothing recompiles.
* Retirement is immediate: the tick a row samples EOS (or hits its token
  budget / the cache ceiling) it is released, and the next queued request
  can be admitted into that slot on the same tick's admission pass.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .sampling import GREEDY, SamplingParams


@dataclass
class Request:
    """One generation request. `out` accumulates generated token ids;
    `on_token` (if set) streams each token as it is sampled. Timing fields
    are wall-clock (perf_counter) and filled by the engine for latency
    accounting: t_submit at submit, t_first_token at the first sampled
    token, t_done at retirement."""

    prompt: List[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=lambda: GREEDY)
    out: List[int] = field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable[["Request", int], None]] = None
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


# Slot states
FREE = "free"
PREFILL = "prefill"
DECODE = "decode"


@dataclass
class SlotEntry:
    """Scheduler-side state of one occupied cache-pool slot."""

    slot: int
    req: Request
    chunk: int  # prefill chunk size the prompt was split into
    n_chunks: int
    left_pad: int  # invalid tokens prepended to the first chunk
    next_chunk: int = 0
    pos: int = 0  # absolute position the next input token writes
    n_generated: int = 0
    state: str = PREFILL

    def prefill_done(self) -> bool:
        return self.next_chunk >= self.n_chunks

    def take_chunk(self):
        """Token ids + positions of the next prompt chunk (lists of length
        `chunk`; positions are -1 on the left pad)."""
        assert self.state == PREFILL and not self.prefill_done()
        j = self.next_chunk
        p = self.req.prompt
        toks, poss = [], []
        for i in range(j * self.chunk, (j + 1) * self.chunk):
            k = i - self.left_pad  # index into the real prompt
            if k < 0:
                toks.append(0)
                poss.append(-1)
            else:
                toks.append(int(p[k]))
                poss.append(k)
        self.next_chunk += 1
        if self.prefill_done():
            self.state = DECODE
            self.pos = len(p)
        return toks, poss


class Scheduler:
    def __init__(self, prefill_chunk: int, max_len: int,
                 eos_id: Optional[int] = None):
        assert prefill_chunk >= 1
        self.prefill_chunk = prefill_chunk
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque = deque()
        self.live: Dict[int, SlotEntry] = {}

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def has_queued(self) -> bool:
        return bool(self.queue)

    def pending(self) -> bool:
        return bool(self.queue or self.live)

    def bind(self, slot: int) -> SlotEntry:
        """Admit the oldest queued request into `slot` (caller acquired it
        from the cache pool, i.e. the row is clean)."""
        req = self.queue.popleft()
        p = len(req.prompt)
        assert p >= 1, "empty prompt"
        c = self.prefill_chunk
        n_chunks = -(-p // c)
        entry = SlotEntry(
            slot=slot, req=req, chunk=c, n_chunks=n_chunks,
            left_pad=n_chunks * c - p,
        )
        self.live[slot] = entry
        return entry

    # -- tick queries ------------------------------------------------------

    def next_prefill(self) -> Optional[SlotEntry]:
        """Oldest slot still prefilling (FIFO over bind order — dict
        preserves insertion order)."""
        for e in self.live.values():
            if e.state == PREFILL:
                return e
        return None

    def decode_entries(self) -> List[SlotEntry]:
        return [e for e in self.live.values() if e.state == DECODE]

    # -- retirement --------------------------------------------------------

    def record_token(self, entry: SlotEntry, token: int) -> bool:
        """Account one sampled token for a DECODE row; returns True if the
        request retired (caller must release the slot to the pool)."""
        req = entry.req
        now = time.perf_counter()
        if not req.out:
            req.t_first_token = now
        req.out.append(token)
        entry.n_generated += 1
        if req.on_token is not None:
            req.on_token(req, token)
        hit_eos = self.eos_id is not None and token == self.eos_id
        out_of_budget = entry.n_generated >= req.max_new_tokens
        cache_full = entry.pos >= self.max_len
        if hit_eos or out_of_budget or cache_full:
            req.done = True
            req.t_done = now
            del self.live[entry.slot]
            entry.state = FREE
            return True
        return False
