"""Continuous-batching scheduler: admission queue + per-slot state machine.

Pure host-side bookkeeping — no jax in this module — so the policy is unit
testable without a model. The engine (engine.py) owns the device work and
drives one `Scheduler` through ticks:

    FREE --admit/bind--> PREFILL --last chunk--> DECODE --EOS/len--> FREE

* Admission is FIFO. A request is bound to a cache-pool slot the moment one
  is free; its prompt is then fed in fixed-size chunks (one chunk per engine
  tick, interleaved with decode steps so running requests keep streaming
  while a long prompt loads).
* Chunks are RIGHT-ALIGNED: the first chunk is left-padded with position -1
  tokens (exact no-ops at every layer), so every chunk is shape (1, C), the
  last real token always sits at index C-1, and chunk count is the only
  per-request variable — shapes never change, nothing recompiles.
* Retirement is immediate: the tick a row samples EOS (or hits its token
  budget / the cache ceiling) it is released, and the next queued request
  can be admitted into that slot on the same tick's admission pass.
* The queue is BOUNDED (``max_queue``): `submit` raises `QueueFull`
  instead of buffering without limit — the reject path load shedding
  (serve/server.py) is built on. Preemption requeues bypass the bound
  (admitted work is never lost to it).
* Abnormal termination is first-class: `finish(entry, reason)` retires a
  live row with finish_reason "cancelled" / "deadline" / "error" and
  `drop_queued` removes a request that never got memory — both leave the
  state machine exactly as a normal retirement does (the caller releases
  backend resources, as with any retirement).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .sampling import GREEDY, SamplingParams


class QueueFull(RuntimeError):
    """submit() on a scheduler whose bounded queue is at capacity."""


@dataclass
class Request:
    """One generation request. `out` accumulates generated token ids;
    `on_token` (if set) streams each token as it is sampled. Timing fields
    are wall-clock (perf_counter) and filled by the engine for latency
    accounting: t_submit at submit, t_first_token at the first sampled
    token, t_done at retirement."""

    prompt: List[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=lambda: GREEDY)
    out: List[int] = field(default_factory=list)
    done: bool = False
    # Why generation stopped: "eos" (sampled the stop token), "length"
    # (max_new_tokens reached), or "cache_ceiling" (prompt+generation hit
    # the engine's max_len — a truncation, NOT a normal completion; the
    # bench and examples report it separately). Abnormal terminals:
    # "cancelled" (client cancellation), "deadline" (TTFT/total deadline
    # expired), "shed" (admission control rejected it), "error" (the row
    # produced non-finite logits and was retired to protect the batch).
    # None while running.
    finish_reason: Optional[str] = None
    # Deadlines, in seconds RELATIVE to t_submit (None = none). The
    # engine's tick loop expires them: ttft_deadline_s while no token has
    # been delivered, deadline_s against total residency — queued or
    # live, the request finishes with finish_reason="deadline" and every
    # resource it held is released that same tick.
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None
    on_token: Optional[Callable[["Request", int], None]] = None
    t_submit: float = 0.0
    # First bind to a slot (queue time = t_admitted - t_submit); a
    # preemption retry keeps the ORIGINAL admission time.
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    # Set after a preemption: the retry must not pin prefix-cache blocks,
    # so eviction can always reclaim enough memory to finish it.
    no_prefix_cache: bool = False
    # Tokens already streamed via on_token before a preemption; the retry
    # replays the identical seeded stream, so callbacks stay suppressed
    # until generation passes this watermark (no duplicate streaming).
    stream_resume: int = 0
    # Trace timeline: (perf_counter, span_kind, attrs) events appended by
    # serve/tracing.py when the engine runs with trace=True; None when
    # tracing is off (the untraced cost is one `is None` check).
    spans: Optional[List[tuple]] = None


# Slot states
FREE = "free"
PREFILL = "prefill"
DECODE = "decode"


@dataclass
class SlotEntry:
    """Scheduler-side state of one occupied cache-pool slot.

    ``start_pos`` is the first prompt position prefill actually runs —
    positions [0, start_pos) were served out of the paged backend's
    prefix cache and already sit in this row's block table. Always 0 on
    the contiguous backend."""

    slot: int
    req: Request
    chunk: int  # prefill chunk size the prompt was split into
    n_chunks: int
    left_pad: int  # invalid tokens prepended to the first chunk
    start_pos: int = 0
    next_chunk: int = 0
    pos: int = 0  # absolute position the next input token writes
    n_generated: int = 0
    state: str = PREFILL

    def prefill_done(self) -> bool:
        return self.next_chunk >= self.n_chunks

    def take_chunk(self):
        """Token ids + positions of the next prompt chunk (lists of length
        `chunk`; positions are -1 on the left pad)."""
        assert self.state == PREFILL and not self.prefill_done()
        j = self.next_chunk
        p = self.req.prompt
        toks, poss = [], []
        for i in range(j * self.chunk, (j + 1) * self.chunk):
            k = self.start_pos + (i - self.left_pad)  # prompt index
            if k < self.start_pos:
                toks.append(0)
                poss.append(-1)
            else:
                toks.append(int(p[k]))
                poss.append(k)
        self.next_chunk += 1
        if self.prefill_done():
            self.state = DECODE
            self.pos = len(p)
        return toks, poss


class Scheduler:
    def __init__(self, prefill_chunk: int, max_len: int,
                 eos_id: Optional[int] = None,
                 max_queue: Optional[int] = None):
        assert prefill_chunk >= 1
        assert max_queue is None or max_queue >= 1
        self.prefill_chunk = prefill_chunk
        self.max_len = max_len
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.queue: deque = deque()
        self.live: Dict[int, SlotEntry] = {}

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise QueueFull(
                f"admission queue at capacity ({self.max_queue})"
            )
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def has_queued(self) -> bool:
        return bool(self.queue)

    def peek(self) -> Request:
        """Oldest queued request (admission decisions inspect the prompt
        before committing memory)."""
        return self.queue[0]

    def pending(self) -> bool:
        return bool(self.queue or self.live)

    def bind(self, slot: int, start_pos: int = 0) -> SlotEntry:
        """Admit the oldest queued request into `slot` (caller acquired it
        from the cache backend, i.e. the row/table is ready). With
        ``start_pos`` > 0, prefill covers only prompt[start_pos:] — the
        prefix-cache hit path."""
        req = self.queue.popleft()
        if req.t_admitted == 0.0:
            req.t_admitted = time.perf_counter()
        p = len(req.prompt)
        assert p >= 1, "empty prompt"
        assert 0 <= start_pos < p, "must re-run at least the last token"
        c = self.prefill_chunk
        tail = p - start_pos
        n_chunks = -(-tail // c)
        entry = SlotEntry(
            slot=slot, req=req, chunk=c, n_chunks=n_chunks,
            left_pad=n_chunks * c - tail, start_pos=start_pos,
        )
        self.live[slot] = entry
        return entry

    def requeue(self, entry: SlotEntry):
        """Preemption: put a live request back at the FRONT of the queue
        with a full restart (its memory was reclaimed — generated tokens
        are discarded and will be regenerated; per-request seeded sampling
        replays the identical stream)."""
        del self.live[entry.slot]
        entry.state = FREE
        req = entry.req
        req.stream_resume = max(req.stream_resume, len(req.out))
        req.out = []
        req.done = False
        req.finish_reason = None
        self.queue.appendleft(req)

    # -- tick queries ------------------------------------------------------

    def next_prefill(self) -> Optional[SlotEntry]:
        """Oldest slot still prefilling (FIFO over bind order — dict
        preserves insertion order)."""
        for e in self.live.values():
            if e.state == PREFILL:
                return e
        return None

    def decode_entries(self) -> List[SlotEntry]:
        return [e for e in self.live.values() if e.state == DECODE]

    # -- abnormal termination ----------------------------------------------

    def finish(self, entry: SlotEntry, reason: str):
        """Retire a LIVE row without a final token: cancellation, deadline
        expiry, or a poisoned-row error. Leaves the state machine exactly
        as `record_token` retirement does — the caller must release the
        slot's backend resources, same as any retirement."""
        req = entry.req
        req.done = True
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        del self.live[entry.slot]
        entry.state = FREE

    def drop_queued(self, req: Request, reason: str) -> bool:
        """Finish a request that is still QUEUED (never bound to memory):
        deadline expiry before admission, or an explicit cancellation.
        Returns False if `req` is not in the queue."""
        try:
            self.queue.remove(req)
        except ValueError:
            return False
        req.done = True
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        return True

    def entry_for(self, req: Request) -> Optional[SlotEntry]:
        """The live slot entry serving `req`, if any."""
        for e in self.live.values():
            if e.req is req:
                return e
        return None

    # -- retirement --------------------------------------------------------

    def record_token(self, entry: SlotEntry, token: int) -> bool:
        """Account one sampled token for a DECODE row; returns True if the
        request retired (caller must release the slot to the pool)."""
        req = entry.req
        now = time.perf_counter()
        # t_first_token == 0.0 means never delivered: a preemption retry
        # keeps the ORIGINAL first-token time (those tokens reached the
        # caller; the replay is internal), so TTFT stays honest.
        if not req.out and req.t_first_token == 0.0:
            req.t_first_token = now
        req.out.append(token)
        entry.n_generated += 1
        if req.on_token is not None and len(req.out) > req.stream_resume:
            req.on_token(req, token)
        hit_eos = self.eos_id is not None and token == self.eos_id
        out_of_budget = entry.n_generated >= req.max_new_tokens
        cache_full = entry.pos >= self.max_len
        if hit_eos or out_of_budget or cache_full:
            # EOS dominates (a natural stop even at the budget edge);
            # cache_ceiling only when nothing else explains the stop, so
            # a truncation is never mislabeled as a completion.
            req.finish_reason = (
                "eos" if hit_eos else
                "length" if out_of_budget else "cache_ceiling"
            )
            req.done = True
            req.t_done = now
            del self.live[entry.slot]
            entry.state = FREE
            return True
        return False

    def record_tokens(self, entry: SlotEntry, tokens) -> "tuple[int, bool]":
        """Account a speculative burst for a DECODE row: commit `tokens`
        in order with exactly `record_token`'s EOS/budget/ceiling
        accounting, TRUNCATING at the first stop — tokens an accepted
        draft carried past an EOS are discarded, never appended to
        ``req.out`` and never streamed. ``entry.pos`` must be the write
        position of the row's pending token (the burst's verify lane 0);
        it advances to each committed token's write position before its
        accounting, mirroring the one-token path where `record_token`
        runs with ``entry.pos`` at the recorded token's write position.
        Returns ``(n_committed, finished)``."""
        n = 0
        for tok in tokens:
            entry.pos += 1
            n += 1
            if self.record_token(entry, int(tok)):
                return n, True
        return n, False
