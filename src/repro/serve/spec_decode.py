"""Speculative decoding over the serving cache pool.

The per-step cost of decode is one full model call per generated token;
speculation breaks that coupling losslessly: a cheap DRAFTER proposes up
to k tokens per row, a single batched (B, k+1) VERIFY step runs them all
through the target model (the chunked-prefill continuation path — one
jitted fixed-shape program, zero recompiles under churn), and an ACCEPT
step commits a prefix of the draft plus one boundary token. Acceptance
is exact-match against the baseline sampler's own chain (greedy: the
argmax; sampled: the categorical draw on the identical
``fold_in(seed, step)`` key — see sampling.spec_accept_tokens), so the
served stream is TOKEN-FOR-TOKEN the non-speculative engine's at every
temperature, with the same acceptance probability a point-mass-drafter
rejection sampler (Leviathan et al. / Chen et al.) would give.

Drafting is SELF-drafting by default: `NgramDrafter` proposes the
continuation of the most recent earlier occurrence of the context's
trailing n-gram (prompt-lookup decoding) — no second model, and very
effective on repetitive continuations, retrieval-grounded prompts, and
code. The `Drafter` interface is one method, so a small draft LM can
slot in later without touching the engine.

Rollback invariants (tested in tests/test_spec_decode.py):

* Rejected draft tokens DID write KV during the verify (write-then-read
  is the chunked-prefill contract). Their entries are unreachable by
  construction — every rejected position is strictly beyond the row's
  committed frontier, so causal masking hides it from every future query
  until the row's own writes overwrite it — and the engine additionally
  scrubs them (pos -> -1) so the cache state is *equal* to never having
  drafted, not merely indistinguishable.
* The paged backend un-reserves blocks that only held rejected tokens
  (`rollback_burst`): block tables and refcounts after a rollback match
  the non-speculative path exactly.
* Per-request RNG counters advance by the tokens a burst actually
  committed, and the token at step s is a pure function of (context,
  seed, s) — independent of burst layout, draft quality, or transient
  memory pressure — so a preempted request replays the identical stream
  on its retry at ANY temperature (burst boundaries may differ on the
  replay; the tokens cannot).

Backend support: the paged backend is fully supported (no ring — every
position owns a unique (block, offset), so stale writes can always be
rolled back); the contiguous backend is supported when its rings never
wrap (no sliding-window layer shorter than max_len — on a wrapped ring a
rejected write EVICTS a live entry, which cannot be restored). SSM/
hybrid archs are rejected: recurrent state advanced by a rejected token
cannot be rewound. Sparse-MoE archs are fully supported: serving routes
each row's tokens independently and droplessly (core/sparse_moe.py), so
the (B, k+1) verify forward == k+1 single decode steps exactly and
rollback stays exact — the lifted restriction the batch-invariant
routing refactor paid for (tests/test_spec_decode.py pins greedy parity
on both MoE archs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import finite_rows, spec_accept_tokens
from .tracing import SPAN_DECODE_TICK, SPAN_SPEC_BURST


class Drafter(Protocol):
    """Anything that proposes draft tokens from a row's committed context
    (prompt + generated so far, ending with the pending token). MUST be
    deterministic in the context: preemption replay and the jit-cache
    guarantees rely on drafts being a pure function of the tokens."""

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        """Up to `k` draft tokens continuing `context` ([] = no draft —
        the row falls back to a plain one-token step this tick)."""
        ...


class NgramDrafter:
    """Prompt-lookup self-drafting: find the most recent earlier
    occurrence of the context's trailing n-gram (longest n first) and
    propose the tokens that followed it. O(n_gram * len) per call on the
    host — contexts are at most max_len tokens, and the scan is trivially
    cheap next to a model call."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert 1 <= min_n <= max_n
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        ctx = list(context)
        for n in range(min(self.max_n, len(ctx) - 1), self.min_n - 1, -1):
            tail = ctx[-n:]
            # scan right-to-left: most recent match wins (recency beats
            # frequency for continuation prediction)
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i: i + n] == tail:
                    cont = ctx[i + n: i + n + k]
                    if cont:
                        return cont
        return []


@dataclass
class SpecConfig:
    """Engine-facing speculative-decoding knobs.

    ``k``: draft tokens per verify step (the verify program's fixed lane
    count is k+1). ``drafter``: any `Drafter`; None = NgramDrafter with
    the given n-gram bounds.

    Graceful degradation: a misbehaving drafter must never take the
    engine down — it can only cost speed. ``disable_after_rejects``
    consecutive fully-rejected bursts on one row turn drafting OFF for
    that row (it keeps decoding correctly at one committed token per
    verify, lane 0 only); ``max_drafter_errors`` drafter exceptions on a
    row do the same. 0 disables either trigger. Per-row state resets
    when the slot turns over to a new request."""

    k: int = 4
    ngram_max: int = 3
    ngram_min: int = 1
    drafter: Optional[Drafter] = None
    disable_after_rejects: int = 8
    max_drafter_errors: int = 2


class SpecDecoder:
    """Drives one ServeEngine's decode phase speculatively.

    Owns the per-slot pending token (sampled, recorded, streamed — but
    its KV not yet written; it rides verify lane 0 next tick), the
    drafter, the jitted accept program, and the acceptance stats the
    bench reports. The engine delegates `_do_decode` here when
    speculation is enabled; admission, prefill, preemption and retirement
    stay engine-owned.
    """

    def __init__(self, engine, cfg: SpecConfig):
        mcfg = engine.cfg
        if cfg.k < 1:
            raise ValueError("SpecConfig.k must be >= 1")
        if mcfg.has_ssm():
            raise ValueError(
                "speculative decoding needs a rollbackable cache; "
                "recurrent SSM state advanced by a rejected draft cannot "
                "be rewound"
            )
        if mcfg.attention is None:
            raise ValueError("speculative decoding needs an attention LM")
        from .cache_pool import ContiguousBackend
        a = mcfg.attention
        if (isinstance(engine.backend, ContiguousBackend)
                and a.sliding_window is not None
                and a.sliding_window < engine.max_len):
            raise ValueError(
                "speculative decoding on the contiguous backend needs "
                "non-wrapping rings (sliding_window < max_len evicts live "
                "entries on a rejected write); use backend='paged', which "
                "stores every position and masks the window instead"
            )
        if cfg.k + 1 > engine.backend.max_chunk:
            raise ValueError(
                f"spec k={cfg.k} exceeds the backend burst limit "
                f"({engine.backend.max_chunk - 1})"
            )
        self.eng = engine
        self.k = cfg.k
        self.cfg_spec = cfg
        self.drafter = cfg.drafter or NgramDrafter(cfg.ngram_max,
                                                   cfg.ngram_min)
        self._accept = jax.jit(spec_accept_tokens)
        self._finite = jax.jit(finite_rows)
        # pending[slot] = sampled-but-not-fed token id (-1 = none); it is
        # already in req.out/streamed — only its KV write is outstanding.
        self._pending = np.full((engine.batch,), -1, np.int64)
        # Per-row degradation state: consecutive fully-rejected bursts,
        # drafter exceptions, and the resulting draft kill-switch. All
        # reset when the slot turns over (drop_slot).
        self._reject_streak = np.zeros((engine.batch,), np.int32)
        self._drafter_errs = np.zeros((engine.batch,), np.int32)
        self._draft_disabled = np.zeros((engine.batch,), bool)
        # stats (bench_serve reports these)
        self.verify_calls = 0
        self.drafted = 0
        self.accepted = 0
        self.tokens_emitted = 0
        self.rows_disabled = 0  # rows whose drafting was auto-disabled
        self.drafter_errors = 0  # drafter exceptions swallowed

    # -- stats -------------------------------------------------------------

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target model accepted."""
        return self.accepted / max(self.drafted, 1)

    def calls_per_token(self) -> float:
        """BATCHED verify calls per emitted token across all rows (one
        verify advances every live row). For the per-row
        calls-per-token metric the spec-decoding literature quotes,
        normalize by the live batch size — benchmarks/bench_serve.py
        does, and gates it < 1.0."""
        return self.verify_calls / max(self.tokens_emitted, 1)

    def drop_slot(self, slot: int):
        """Forget a slot's pending token and degradation state
        (preemption/retirement — the next occupant starts clean)."""
        self._pending[slot] = -1
        self._reject_streak[slot] = 0
        self._drafter_errs[slot] = 0
        self._draft_disabled[slot] = False

    def _disable_row(self, slot: int):
        if not self._draft_disabled[slot]:
            self._draft_disabled[slot] = True
            self.rows_disabled += 1

    def _propose(self, slot: int, entry, n: int) -> List[int]:
        """Draft for one row, tolerating a hostile drafter: exceptions
        are swallowed (and counted toward the row's kill-switch) and
        out-of-vocab token ids are truncated at — a garbage id would
        index the embedding out of range. A disabled row drafts
        nothing and decodes correctly at one token per verify."""
        if self._draft_disabled[slot]:
            return []
        try:
            drafts = list(self.drafter.propose(
                list(entry.req.prompt) + list(entry.req.out), n
            ))[:n]
        except Exception:
            self.drafter_errors += 1
            self._drafter_errs[slot] += 1
            ma = self.cfg_spec.max_drafter_errors
            if ma and self._drafter_errs[slot] >= ma:
                self._disable_row(slot)
            return []
        vocab = self.eng.cfg.vocab_size
        for i, t in enumerate(drafts):
            if not (0 <= int(t) < vocab):
                return drafts[:i]
        return drafts

    def reset_stats(self):
        """Zero the speculation counters (bench warmup: compile runs must
        not pollute the measured acceptance rate)."""
        self.verify_calls = 0
        self.drafted = 0
        self.accepted = 0
        self.tokens_emitted = 0
        self.rows_disabled = 0
        self.drafter_errors = 0

    # -- the tick ----------------------------------------------------------

    def decode_tick(self) -> int:
        """Speculative replacement for ServeEngine._do_decode: phase 1
        samples first tokens for rows fresh out of prefill (from the
        prefill logits, exactly like the baseline engine — no model
        call); phase 2 drafts, verifies in one (B, k+1) model call,
        accepts via rejection sampling, commits with EOS/budget/ceiling
        truncation, and rolls back rejected state. Returns tokens
        emitted this tick."""
        eng = self.eng
        sched = eng.sched
        entries = sched.decode_entries()
        if not entries:
            return 0
        emitted_total = 0

        fresh = [e for e in entries if self._pending[e.slot] < 0]
        if fresh:
            toks, ok = eng._sample(
                eng._logits, eng._temp, eng._top_k, eng._top_p,
                eng._seed, eng._step,
            )
            toks, ok = np.asarray(toks), np.asarray(ok)
            for e in fresh:
                if not ok[e.slot]:
                    eng._abort_entry(e, "error")
                    eng.nonfinite_retired += 1
                    continue
                tok = int(toks[e.slot])
                eng._step[e.slot] += 1
                emitted_total += 1
                self.tokens_emitted += 1
                finished = sched.record_token(e, tok)
                if eng.tracer is not None:
                    eng.tracer.span(e.req, SPAN_DECODE_TICK, token=tok)
                if finished:
                    eng._retire_entry(e)
                else:
                    self._pending[e.slot] = tok

        live = [e for e in entries if self._pending[e.slot] >= 0]
        if not live:
            return emitted_total

        k = self.k
        in_toks = np.full((eng.batch, k + 1), eng.pad_id, np.int32)
        in_pos = np.full((eng.batch, k + 1), -1, np.int32)
        n_draft = np.zeros((eng.batch,), np.int32)
        plans = {}  # slot -> (entry, lane-0 write position)
        for e in list(live):
            slot = e.slot
            # cap drafts at the remaining budget (tokens past it would be
            # truncated anyway) and the cache ceiling (a position >=
            # max_len has no slot to write — and on a ring it would wrap
            # onto live entries)
            budget_left = e.req.max_new_tokens - e.n_generated
            k_cap = max(0, min(k, budget_left - 1, eng.max_len - 1 - e.pos))
            cover = eng.backend.reserve_burst(slot, e.pos, k_cap + 1)
            if cover <= 0:
                eng._preempt(e)  # drops this slot's pending token too
                continue
            drafts = []
            if cover > 1:
                drafts = self._propose(slot, e, cover - 1)
            m = len(drafts)
            in_toks[slot, 0] = self._pending[slot]
            if m:
                in_toks[slot, 1: 1 + m] = drafts
            in_pos[slot, : 1 + m] = e.pos + np.arange(1 + m)
            n_draft[slot] = m
            self.drafted += m
            plans[slot] = (e, e.pos)
        if not plans:
            return emitted_total

        logits = eng.backend.verify(
            eng.params, jnp.asarray(in_toks), jnp.asarray(in_pos)
        )
        eng.decode_steps += 1
        self.verify_calls += 1
        n_acc, out_toks = self._accept(
            logits, jnp.asarray(in_toks[:, 1:]), jnp.asarray(n_draft),
            eng._temp, eng._top_k, eng._top_p, eng._seed, eng._step,
        )
        n_acc = np.asarray(n_acc)
        out_toks = np.asarray(out_toks)
        row_ok = np.asarray(self._finite(logits))

        # Rejected-lane scrub: positions the verify wrote that acceptance
        # disowned (lanes n_acc+1 .. n_draft). One fixed-shape program
        # per tick — run even when empty so its jit cache is warmed
        # deterministically (zero-recompile accounting).
        inval = np.full((eng.batch, k + 1), -1, np.int32)
        rollbacks = []
        for slot, (e, base) in plans.items():
            if not row_ok[slot]:
                # Poisoned verify logits: nothing this row produced can
                # be trusted — retire it (releasing its burst blocks
                # wholesale) rather than committing NaN-derived tokens.
                eng._abort_entry(e, "error")
                eng.nonfinite_retired += 1
                continue
            na = int(n_acc[slot])
            m = int(n_draft[slot])
            if m and na == 0:
                self._reject_streak[slot] += 1
                lim = self.cfg_spec.disable_after_rejects
                if lim and self._reject_streak[slot] >= lim:
                    self._disable_row(slot)
            elif na:
                self._reject_streak[slot] = 0
            burst = [int(t) for t in out_toks[slot, : na + 1]]
            committed, finished = sched.record_tokens(e, burst)
            eng._step[slot] += committed
            emitted_total += committed
            self.tokens_emitted += committed
            self.accepted += na
            if eng.tracer is not None:
                eng.tracer.span(e.req, SPAN_SPEC_BURST, drafted=m,
                                accepted=na, committed=committed)
            if finished:
                eng._retire_entry(e)  # drops the pending token too
            else:
                # committed == len(burst) here (no truncation), so the
                # last burst token is the new pending; e.pos is now its
                # write position.
                self._pending[slot] = burst[-1]
                rej = np.arange(na + 1, int(n_draft[slot]) + 1)
                if rej.size:
                    inval[slot, rej] = base + rej
                rollbacks.append((slot, e.pos))
        eng.backend.invalidate_positions(jnp.asarray(inval))
        for slot, next_pos in rollbacks:
            eng.backend.rollback_burst(slot, next_pos)
        return emitted_total
