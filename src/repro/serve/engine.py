"""Batched serving engine: prefill + single-token decode over a fixed-shape
KV cache pool.

``make_prefill_step`` / ``make_decode_step`` are the functions the dry-run
lowers for the prefill/decode input shapes: decode processes ONE new token
per sequence against a cache of `max_len` (the brief's decode_32k /
long_500k semantics).

The engine batches requests *generation-synchronously*: a wave of requests
is admitted together (prompts right-padded to a common length), decoded in
lockstep, and the next wave admits when the wave finishes. Rows that hit
EOS early are masked out but their cache row is only reused at the wave
boundary — positions are shared across the batch, which keeps the cache's
ring-buffer position index global and the decode step free of per-row
gather/scatter. Full continuous batching would move `pos` into the cache
as a per-row array; noted as an extension in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from ..models import init_cache, lm_apply


def make_prefill_step(cfg, max_len: int):
    """(params, tokens(B,S), cache) -> (logits(B,1,V), cache)."""

    def prefill(params, tokens, cache):
        s = tokens.shape[1]
        logits, cache, _ = lm_apply(
            params, cfg, tokens, positions=jnp.arange(s), cache=cache,
            mode="prefill", last_only=True,
        )
        return logits, cache

    return prefill


def make_decode_step(cfg):
    """(params, tokens(B,1), pos(), cache) -> (logits(B,1,V), cache)."""

    def decode(params, tokens, pos, cache):
        logits, cache, _ = lm_apply(
            params, cfg, tokens, positions=pos[None], cache=cache,
            mode="decode",
        )
        return logits, cache

    return decode


def sample_greedy(rng, logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def sample_temperature(rng, logits, temperature: float = 1.0):
    return jax.random.categorical(
        rng, logits[:, -1].astype(jnp.float32) / max(temperature, 1e-6)
    ).astype(jnp.int32)


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 sampler: Callable = sample_greedy, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sampler = sampler
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_decode_step(cfg))
        self.queue: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _next_wave(self) -> List[Request]:
        wave = self.queue[: self.batch]
        self.queue = self.queue[self.batch:]
        return wave

    def _run_wave(self, wave: List[Request]) -> int:
        plen = max(len(r.prompt) for r in wave)
        toks = jnp.full((self.batch, plen), self.pad_id, jnp.int32)
        for i, r in enumerate(wave):
            # right-align so the last prompt token sits at position plen-1
            toks = toks.at[i, plen - len(r.prompt):].set(
                jnp.asarray(r.prompt, jnp.int32)
            )
        cache = init_cache(self.cfg, self.batch, self.max_len)
        logits, cache = self._prefill(self.params, toks, cache)
        self.rng, r_s = jax.random.split(self.rng)
        nxt = self.sampler(r_s, logits)
        for i, r in enumerate(wave):
            r.out.append(int(nxt[i]))
        steps = 0
        budget = max(r.max_new_tokens for r in wave)
        pos = plen
        cur = nxt[:, None]
        while steps < budget - 1 and pos < self.max_len:
            logits, cache = self._decode(
                self.params, cur, jnp.asarray(pos, jnp.int32), cache
            )
            self.rng, r_s = jax.random.split(self.rng)
            nxt = self.sampler(r_s, logits)
            for i, r in enumerate(wave):
                if not r.done and len(r.out) < r.max_new_tokens:
                    tok = int(nxt[i])
                    r.out.append(tok)
                    if self.eos_id is not None and tok == self.eos_id:
                        r.done = True
            cur = nxt[:, None]
            pos += 1
            steps += 1
        for r in wave:
            r.done = True
        return steps + 1

    def run(self) -> int:
        total = 0
        while self.queue:
            total += self._run_wave(self._next_wave())
        return total
