"""Serving engines over the per-row KV/SSM cache pool.

``ServeEngine`` is the continuous-batching engine: requests are admitted
the moment a cache-pool slot frees, prompts prefill in fixed-size chunks
interleaved with decode steps, every decode tick advances ALL live rows in
one batched model call, and a row retires (slot released, next request
admitted) the tick it samples EOS or exhausts its budget. Sampling is the
batched per-request suite from sampling.py.

Three jitted device programs run the whole serving loop, each with ONE
fixed shape — request churn never triggers a recompile (asserted via
``jax.jit`` cache stats in tests/test_serve.py):

* prefill-chunk: (params, pool, logits_buf, slot, tokens(1,C), pos(1,C))
  — slices the slot's batch-1 cache row out of the pool, runs the model in
  chunked-prefill mode (attends prior chunks through the cache), scatters
  the row back, and on every chunk writes the last-position logits into
  row `slot` of the persistent (num_slots, vocab) logits buffer (only the
  final chunk's write is ever consumed).
* decode: (params, pool, tokens(B,1), positions(B,)) — one token for every
  slot; inactive rows carry position -1, which the model turns into a
  no-op (no cache write, no state update, masked from attention).
* sample: sampling.sample_tokens over the logits buffer with per-slot
  parameter arrays.

``WaveEngine`` keeps the old wave-synchronous behaviour (admit a full
batch, decode in lockstep, free slots only at the wave boundary) as the
benchmark baseline for benchmarks/bench_serve.py.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import init_cache, lm_apply
from .cache_pool import CachePool, pool_row, pool_write_row
from .sampling import GREEDY, SamplingParams, sample_tokens
from .scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# jitted step factories (also lowered standalone by launch/specs.py)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, max_len: int):
    """Whole-prompt prefill: (params, tokens(B,S), cache) ->
    (logits(B,1,V), cache). Shared positions arange(S) — the wave path and
    the dry-run's prefill cells."""

    def prefill(params, tokens, cache):
        s = tokens.shape[1]
        logits, cache, _ = lm_apply(
            params, cfg, tokens, positions=jnp.arange(s), cache=cache,
            mode="prefill", last_only=True,
        )
        return logits, cache

    return prefill


def make_decode_step(cfg):
    """(params, tokens(B,1), pos(B,), cache) -> (logits(B,1,V), cache).
    Per-row positions; rows with pos<0 are inactive no-ops."""

    def decode(params, tokens, pos, cache):
        logits, cache, _ = lm_apply(
            params, cfg, tokens, positions=pos[:, None], cache=cache,
            mode="decode",
        )
        return logits, cache

    return decode


def make_prefill_chunk_step(cfg):
    """Chunked prefill into one pool slot: (params, pool_cache, logits_buf,
    slot, tokens(1,C), positions(1,C)) -> (pool_cache, logits_buf).

    mode="decode" with S>1 makes attention read prior chunks back out of
    the cache (and the SSM paths continue from their recurrent state), so
    chunks compose exactly; left-pad tokens carry position -1 and touch
    nothing."""

    def prefill_chunk(params, cache, buf, slot, tokens, positions):
        row = pool_row(cache, slot)
        logits, row, _ = lm_apply(
            params, cfg, tokens, positions=positions, cache=row,
            mode="decode", last_only=True,
        )
        cache = pool_write_row(cache, slot, row)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, logits[:, -1].astype(buf.dtype), slot, axis=0
        )
        return cache, buf

    return prefill_chunk


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous-batching serving engine.

    batch_size is the number of cache-pool slots (= max concurrent
    requests); max_len caps prompt+generation per request. Per-request
    sampling comes from Request.sampling; ``default_sampling`` fills in
    for requests that keep the dataclass default.
    """

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 default_sampling: SamplingParams = GREEDY, seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.pad_id = pad_id
        self.default_sampling = default_sampling
        self.seed = seed
        self.pool = CachePool(cfg, batch_size, max_len, cache_dtype)
        chunk = prefill_chunk or min(32, self.pool.min_ring_len)
        assert chunk <= self.pool.min_ring_len, (
            f"prefill_chunk {chunk} would wrap the smallest ring buffer "
            f"({self.pool.min_ring_len}) inside one scatter"
        )
        self.sched = Scheduler(chunk, max_len, eos_id)
        # Donate the cache (and logits buffer) so XLA aliases them in
        # place instead of materializing a second full pool every tick
        # (no-op on CPU, which lacks donation — a one-time warning).
        self._prefill_chunk = jax.jit(
            make_prefill_chunk_step(cfg), donate_argnums=(1, 2)
        )
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
        self._sample = jax.jit(sample_tokens)
        # Per-slot logits of the *last* model call that touched the row —
        # valid iff the row is in DECODE state.
        self._logits = jnp.zeros((batch_size, cfg.vocab_size), jnp.float32)
        # Per-slot sampling parameter arrays (host; fixed shapes).
        self._temp = np.zeros((batch_size,), np.float32)
        self._top_k = np.zeros((batch_size,), np.int32)
        self._top_p = np.ones((batch_size,), np.float32)
        self._seed = np.zeros((batch_size,), np.int32)
        self._step = np.zeros((batch_size,), np.int32)
        self.decode_steps = 0  # batched decode model calls (perf counter)

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new({req.max_new_tokens}) "
                f"exceeds max_len {self.max_len}"
            )
        self.sched.submit(req)

    # -- tick phases -------------------------------------------------------

    def _admit(self):
        while self.sched.has_queued() and self.pool.num_free:
            slot = self.pool.acquire()
            entry = self.sched.bind(slot)
            sp = entry.req.sampling
            if sp is GREEDY:
                sp = self.default_sampling
                entry.req.sampling = sp
            self._temp[slot] = sp.temperature
            self._top_k[slot] = sp.top_k
            self._top_p[slot] = sp.top_p
            self._seed[slot] = sp.seed
            self._step[slot] = 0

    def _do_prefill_chunk(self) -> bool:
        entry = self.sched.next_prefill()
        if entry is None:
            return False
        toks, poss = entry.take_chunk()
        self.pool.cache, self._logits = self._prefill_chunk(
            self.params, self.pool.cache, self._logits,
            jnp.int32(entry.slot),
            jnp.asarray([toks], jnp.int32), jnp.asarray([poss], jnp.int32),
        )
        return True

    def _do_decode(self) -> int:
        """Sample every DECODE row from the logits buffer, retire finished
        rows, then one batched decode step for the survivors. Returns the
        number of tokens emitted."""
        entries = self.sched.decode_entries()
        if not entries:
            return 0
        toks = np.asarray(self._sample(
            self._logits, self._temp, self._top_k, self._top_p,
            self._seed, self._step,
        ))
        in_toks = np.full((self.batch, 1), self.pad_id, np.int32)
        in_pos = np.full((self.batch,), -1, np.int32)
        emitted = 0
        survivors = []
        for e in entries:
            tok = int(toks[e.slot])
            self._step[e.slot] += 1
            emitted += 1
            if self.sched.record_token(e, tok):
                self.pool.release(e.slot)
            else:
                in_toks[e.slot, 0] = tok
                in_pos[e.slot] = e.pos
                survivors.append(e)
        if survivors:
            logits, self.pool.cache = self._decode(
                self.params, jnp.asarray(in_toks), jnp.asarray(in_pos),
                self.pool.cache,
            )
            self._logits = logits[:, 0].astype(jnp.float32)
            self.decode_steps += 1
            for e in survivors:
                e.pos += 1
        return emitted

    def step(self) -> int:
        """One engine tick: admit, (maybe) one prefill chunk, one batched
        sample+decode pass. Returns tokens emitted this tick."""
        self._admit()
        self._do_prefill_chunk()
        return self._do_decode()

    def run(self) -> int:
        """Drain queue + live rows to completion; returns total decode
        model calls (the old wave-engine return contract)."""
        while self.sched.pending():
            self.step()
        return self.decode_steps


# ---------------------------------------------------------------------------
# Wave-synchronous baseline (benchmarks)
# ---------------------------------------------------------------------------


class WaveEngine:
    """The pre-continuous engine: a wave of requests admits together,
    decodes in lockstep, and every slot is held until the LAST row of the
    wave finishes. Kept as the baseline bench_serve.py measures against."""

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 default_sampling: SamplingParams = GREEDY, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.default_sampling = default_sampling

        def prefill(params, t, p, cache):
            logits, cache, _ = lm_apply(
                params, cfg, t, positions=p, cache=cache,
                mode="prefill", last_only=True,
            )
            return logits, cache

        # Jitted once; still recompiles per distinct padded prompt length —
        # an inherent wave cost the continuous engine's fixed chunks remove.
        self._prefill = jax.jit(prefill, donate_argnums=(3,))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
        self._sample = jax.jit(sample_tokens)
        self.queue: List[Request] = []
        self.decode_steps = 0

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _sample_wave(self, wave, logits, step_base):
        sp = [
            (r.sampling if r.sampling is not GREEDY else
             self.default_sampling)
            for r in wave
        ] + [GREEDY] * (self.batch - len(wave))
        toks = self._sample(
            logits[:, -1].astype(jnp.float32),
            np.array([p.temperature for p in sp], np.float32),
            np.array([p.top_k for p in sp], np.int32),
            np.array([p.top_p for p in sp], np.float32),
            np.array([p.seed for p in sp], np.int32),
            np.full((self.batch,), step_base, np.int32),
        )
        return np.asarray(toks)

    def _run_wave(self, wave: List[Request]) -> int:
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((self.batch, plen), self.pad_id, np.int32)
        poss = np.full((self.batch, plen), -1, np.int32)
        for i, r in enumerate(wave):
            # right-align so the last prompt token sits at index plen-1
            toks[i, plen - len(r.prompt):] = r.prompt
            poss[i, plen - len(r.prompt):] = np.arange(len(r.prompt))
        cache = init_cache(self.cfg, self.batch, self.max_len)
        logits, cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(poss), cache
        )
        nxt = self._sample_wave(wave, logits, 0)
        now = time.perf_counter()
        for i, r in enumerate(wave):
            r.out.append(int(nxt[i]))
            r.t_first_token = now
        steps = 0
        budget = max(r.max_new_tokens for r in wave)
        pos = np.array([len(r.prompt) for r in wave]
                       + [0] * (self.batch - len(wave)), np.int32)
        cur = nxt[:, None]
        while steps < budget - 1 and int(pos.max()) < self.max_len:
            logits, cache = self._decode(
                self.params, jnp.asarray(cur), jnp.asarray(pos), cache
            )
            self.decode_steps += 1
            nxt = self._sample_wave(wave, logits, steps + 1)
            now = time.perf_counter()
            for i, r in enumerate(wave):
                if not r.done and len(r.out) < r.max_new_tokens:
                    tok = int(nxt[i])
                    r.out.append(tok)
                    if self.eos_id is not None and tok == self.eos_id:
                        r.done = True
                        r.t_done = now
            cur = nxt[:, None]
            pos += 1
            steps += 1
        now = time.perf_counter()
        for r in wave:
            if not r.done:
                r.done = True
                r.t_done = now
        return steps + 1

    def run(self) -> int:
        total = 0
        while self.queue:
            wave = self.queue[: self.batch]
            self.queue = self.queue[self.batch:]
            total += self._run_wave(wave)
        return total
