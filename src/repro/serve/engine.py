"""Serving engines over pluggable cache backends.

``ServeEngine`` is the continuous-batching engine: requests are admitted
the moment the cache backend has memory for them, prompts prefill in
fixed-size chunks interleaved with decode steps, every decode tick
advances ALL live rows in one batched model call, and a row retires (its
memory released, the next request admitted) the tick it samples EOS or
exhausts its budget. Sampling is the batched per-request suite from
sampling.py.

The engine is memory-layout agnostic: it drives a ``CacheBackend``
(serve/cache_pool.py defines the interface) and two are provided —

* ``backend="contiguous"``: one max_len cache row per slot. Admission
  needs a free slot. Bit-exact baseline and correctness oracle.
* ``backend="paged"``: fixed-size KV token blocks with per-request block
  tables, copy-on-write refcounts and a radix-tree prefix cache
  (serve/block_manager.py, serve/prefix_cache.py). Admission needs a
  free slot AND free blocks for the *uncached* part of the prompt;
  decode allocates blocks incrementally and preempts (requeues) a row
  if memory truly runs dry.

Every device program behind either backend has ONE fixed signature —
request churn never triggers a recompile (asserted via ``jax.jit`` cache
stats in tests/test_serve.py and tests/test_serve_paged.py).

Every program also runs the model in a serving mode in which MoE routing
is a pure per-row function (core/sparse_moe.py), so a request's tokens
do not depend on its co-batch, on prefill chunking, or on whether it was
decoded plainly or through a speculative (B, k+1) verify lane
(tests/test_batch_invariance.py pins this token-for-token).

``WaveEngine`` keeps the old wave-synchronous behaviour (admit a full
batch, decode in lockstep, free slots only at the wave boundary) as the
benchmark baseline for benchmarks/bench_serve.py.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import init_cache, lm_apply
from .block_manager import PagedBackend
from .cache_pool import ContiguousBackend
from .programs import (  # noqa: F401  (re-exported; launch/specs.py uses)
    make_decode_step,
    make_prefill_chunk_step,
    make_prefill_step,
)
from .sampling import (
    GREEDY,
    SamplingParams,
    sample_tokens,
    sample_tokens_checked,
)
from .scheduler import Request, Scheduler
from .spec_decode import SpecConfig, SpecDecoder
from .telemetry import TelemetryAggregator
from .tracing import (
    SPAN_ADMITTED,
    SPAN_DECODE_TICK,
    SPAN_KERNEL_FALLBACK,
    SPAN_PREEMPTED,
    SPAN_PREFILL_CHUNK,
    SPAN_REQUEUED,
    SPAN_RETIRED,
    FlightRecorder,
    ProgramTimer,
    Tracer,
)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous-batching serving engine.

    batch_size is the number of decode rows (= max concurrent requests);
    max_len caps prompt+generation per request. Per-request sampling
    comes from Request.sampling; ``default_sampling`` fills in for
    requests that keep the dataclass default.

    backend="paged" extras: ``block_size`` tokens per KV block,
    ``num_blocks`` total pool blocks (default: capacity parity with the
    contiguous pool), ``prefix_cache`` to share common prompt prefixes
    through the radix tree, ``use_kernel`` for the Pallas paged-attention
    decode kernel (default on; off = the jnp row-view gather oracle),
    ``cache_generated`` to also publish retired requests' generated
    tokens into the radix tree (multi-turn prefix reuse).

    ``max_queue`` bounds the admission queue: `submit` raises
    `scheduler.QueueFull` at capacity instead of buffering without limit
    (the reject path serve/server.py builds load shedding on). The tick
    loop enforces per-request deadlines (Request.ttft_deadline_s /
    deadline_s -> finish_reason="deadline"), `cancel(req)` frees a
    queued or live request's every resource within one tick, and rows
    whose logits go non-finite retire with finish_reason="error" instead
    of corrupting the batch.

    ``spec`` (a SpecConfig) turns on speculative decoding
    (serve/spec_decode.py): a self-drafting n-gram drafter proposes up to
    spec.k tokens per row and one batched (B, k+1) verify step commits an
    accepted prefix — the served stream is token-for-token the
    non-speculative engine's at any temperature (exact-match acceptance
    against the baseline sampler's own draws).
    """

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 default_sampling: SamplingParams = GREEDY, seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 cache_dtype=jnp.bfloat16, backend: str = "contiguous",
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefix_cache: bool = True, use_kernel: bool = True,
                 cache_generated: bool = False,
                 spec: Optional[SpecConfig] = None,
                 max_queue: Optional[int] = None,
                 trace: bool = False, flight_recorder: int = 0,
                 telemetry: bool = False):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.pad_id = pad_id
        self.default_sampling = default_sampling
        self.seed = seed
        if backend == "contiguous":
            if cache_generated:
                raise ValueError(
                    "cache_generated needs the paged backend's radix tree"
                )
            self.backend = ContiguousBackend(cfg, batch_size, max_len,
                                             cache_dtype,
                                             telemetry=telemetry)
        elif backend == "paged":
            self.backend = PagedBackend(
                cfg, batch_size, max_len, cache_dtype,
                block_size=block_size, num_blocks=num_blocks,
                prefix_cache=prefix_cache, use_kernel=use_kernel,
                cache_generated=cache_generated, telemetry=telemetry,
            )
        else:
            raise ValueError(f"unknown backend {backend!r}")
        chunk = prefill_chunk or min(32, self.backend.max_chunk)
        assert chunk <= self.backend.max_chunk, (
            f"prefill_chunk {chunk} exceeds backend limit "
            f"{self.backend.max_chunk}"
        )
        self.sched = Scheduler(chunk, max_len, eos_id, max_queue=max_queue)
        # Sampler fused with the per-row non-finite guard: one program
        # returns (tokens, ok); rows whose logits carry NaN/inf are
        # retired with finish_reason="error" instead of committing a
        # garbage token and corrupting the shared batch.
        self._sample = jax.jit(sample_tokens_checked)
        # Per-slot logits of the *last* model call that touched the row —
        # valid iff the row is in DECODE state.
        self._logits = jnp.zeros((batch_size, cfg.vocab_size), jnp.float32)
        # Per-slot sampling parameter arrays (host; fixed shapes).
        self._temp = np.zeros((batch_size,), np.float32)
        self._top_k = np.zeros((batch_size,), np.int32)
        self._top_p = np.ones((batch_size,), np.float32)
        self._seed = np.zeros((batch_size,), np.int32)
        self._step = np.zeros((batch_size,), np.int32)
        self.decode_steps = 0  # batched decode model calls (perf counter)
        self.preemptions = 0
        # Robustness counters (serve/metrics.py collects these).
        self.cancellations = 0
        self.nonfinite_retired = 0
        self.deadline_misses = {"ttft": 0, "total": 0}
        # Speculative decoding: SpecDecoder validates arch/backend support
        # (rollbackable cache) and owns drafting/verify/accept state.
        self._spec = SpecDecoder(self, spec) if spec is not None else None
        # Set by a preemption while other rows are live: admission pauses
        # until one of them RETIRES. Without this barrier two equal-sized
        # rows livelock — the preempted one instantly re-admits into its
        # own freed blocks and starves the other into preempting, forever.
        self._admission_hold = False
        # Observability (serve/tracing.py): per-request span timelines
        # (trace=True) and a bounded ring of per-tick records
        # (flight_recorder=N). Both are host-side only — no jitted
        # program changes, no recompiles, bit-identical served tokens.
        self.tracer = Tracer() if trace else None
        self.recorder = (FlightRecorder(flight_recorder)
                         if flight_recorder else None)
        self.ticks = 0
        self._kfb_seen = getattr(self.backend, "kernel_fallbacks", 0)
        # Model-interior telemetry (serve/telemetry.py): drains the
        # backend's per-call (phase, pytree) stash after each tick phase.
        self.telemetry = TelemetryAggregator() if telemetry else None
        self._timers = {}
        if self.recorder is not None or telemetry:
            # Wrap the backend's public model entry points + the sampler
            # with host-side timers. FaultInjector attaches AFTER engine
            # construction and wraps whatever is bound then, so injected
            # faults stay timed and detach() restores the timed methods.
            # Telemetry builds them too: program_efficiency() joins their
            # measured wall times with the roofline bounds.
            for name in ("prefill_chunk", "decode", "verify"):
                timer = ProgramTimer(name, getattr(self.backend, name))
                setattr(self.backend, name, timer)
                self._timers[name] = timer
            self._sample = ProgramTimer("sample", self._sample)
            self._timers["sample"] = self._sample

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new({req.max_new_tokens}) "
                f"exceeds max_len {self.max_len}"
            )
        if not self.backend.accepts(len(req.prompt), req.max_new_tokens):
            raise ValueError(
                f"request needs more cache than the backend owns "
                f"(prompt {len(req.prompt)} + max_new {req.max_new_tokens})"
            )
        self.sched.submit(req)
        if self.tracer is not None:
            self.tracer.start(req)

    # -- tick phases -------------------------------------------------------

    def _admit(self) -> int:
        admitted = 0
        while self.sched.has_queued() and not self._admission_hold:
            res = self.backend.try_admit(self.sched.peek())
            if res is None:
                break  # FIFO: head blocks until memory frees
            slot, cached_len = res
            entry = self.sched.bind(slot, start_pos=cached_len)
            admitted += 1
            if self.tracer is not None:
                self.tracer.span(entry.req, SPAN_ADMITTED, slot=slot,
                                 cached=cached_len)
            sp = entry.req.sampling
            if sp is GREEDY:
                sp = self.default_sampling
                entry.req.sampling = sp
            self._temp[slot] = sp.temperature
            self._top_k[slot] = sp.top_k
            self._top_p[slot] = sp.top_p
            self._seed[slot] = sp.seed
            self._step[slot] = 0
        return admitted

    def _do_prefill_chunk(self) -> bool:
        entry = self.sched.next_prefill()
        if entry is None:
            return False
        chunk_i = entry.next_chunk
        toks, poss = entry.take_chunk()
        self._logits = self.backend.prefill_chunk(
            self.params, self._logits, entry.slot, toks, poss
        )
        if self.tracer is not None:
            self.tracer.span(entry.req, SPAN_PREFILL_CHUNK, i=chunk_i,
                             of=entry.n_chunks)
        if entry.prefill_done():
            self.backend.prefill_finished(entry)
        return True

    def _preempt(self, entry):
        """Out of cache memory mid-decode: reclaim the row and put the
        request back at the head of the queue for a full restart. Its
        own prefix-cache hits are disabled on the retry so eviction can
        always reclaim enough blocks to finish it."""
        if self.tracer is not None:
            self.tracer.span(entry.req, SPAN_PREEMPTED, slot=entry.slot,
                             discarded=len(entry.req.out))
        self.backend.retire(entry.slot)
        self.sched.requeue(entry)
        if self.tracer is not None:
            self.tracer.span(entry.req, SPAN_REQUEUED)
        entry.req.no_prefix_cache = True
        self.preemptions += 1
        if self._spec is not None:
            self._spec.drop_slot(entry.slot)
        # Hold admission until a live row retires and genuinely frees
        # memory; with no other live row the restart owns the whole pool.
        self._admission_hold = bool(self.sched.live)

    def _retire_entry(self, entry):
        """Normal completion: let the backend publish reusable state
        (generated-token prefix caching), release the slot, and unblock
        admission — memory was genuinely freed."""
        self.backend.cache_finished(entry)
        self.backend.retire(entry.slot)
        if self._spec is not None:
            self._spec.drop_slot(entry.slot)
        self._admission_hold = False
        if self.tracer is not None:
            attrs = {"reason": entry.req.finish_reason}
            if self.telemetry is not None:
                # annotate retirement with the latest decode numerics so a
                # trace shows the model state the request retired under
                flat = self.telemetry.latest.get("decode", {})
                for k in ("logits_max_abs_logit", "logits_softmax_entropy"):
                    if k in flat:
                        attrs[k] = round(flat[k], 6)
            self.tracer.span(entry.req, SPAN_RETIRED, **attrs)

    def _abort_entry(self, entry, reason: str):
        """Abnormal retirement (cancellation / deadline / poisoned row):
        release EVERYTHING the row holds — slot, blocks, pending
        speculative state — in the same tick, without publishing any of
        its (possibly partial or poisoned) state to the prefix cache."""
        self.sched.finish(entry, reason)
        self.backend.retire(entry.slot)
        if self._spec is not None:
            self._spec.drop_slot(entry.slot)
        self._admission_hold = False
        if self.tracer is not None:
            self.tracer.span(entry.req, SPAN_RETIRED, reason=reason)

    def cancel(self, req: Request, reason: str = "cancelled") -> bool:
        """Cancel a request wherever it is: queued (dropped before it
        ever binds memory) or live (the row retires and its slot/blocks/
        pending-spec state free immediately — within the tick the cancel
        lands in). Returns False if the request already finished (its
        output stands; cancellation lost the race)."""
        if req.done:
            return False
        if self.sched.drop_queued(req, reason):
            self.cancellations += 1
            if self.tracer is not None:
                self.tracer.span(req, SPAN_RETIRED, reason=reason)
            return True
        entry = self.sched.entry_for(req)
        if entry is None:
            return False
        self._abort_entry(entry, reason)
        self.cancellations += 1
        return True

    @staticmethod
    def _deadline_kind(req: Request, now: float) -> Optional[str]:
        if (req.deadline_s is not None
                and now - req.t_submit >= req.deadline_s):
            return "total"
        if (req.ttft_deadline_s is not None and req.t_first_token == 0.0
                and now - req.t_submit >= req.ttft_deadline_s):
            return "ttft"
        return None

    def _expire_deadlines(self):
        """Tick-loop deadline enforcement: queued requests whose TTFT or
        total deadline already passed never bind memory; live rows are
        aborted and their resources free this same tick."""
        now = time.perf_counter()
        for req in [r for r in self.sched.queue
                    if r.ttft_deadline_s is not None
                    or r.deadline_s is not None]:
            kind = self._deadline_kind(req, now)
            if kind is not None:
                self.sched.drop_queued(req, "deadline")
                self.deadline_misses[kind] += 1
                if self.tracer is not None:
                    self.tracer.span(req, SPAN_RETIRED, reason="deadline")
        for entry in list(self.sched.live.values()):
            kind = self._deadline_kind(entry.req, now)
            if kind is not None:
                self._abort_entry(entry, "deadline")
                self.deadline_misses[kind] += 1

    def _do_decode(self) -> int:
        """Sample every DECODE row from the logits buffer, retire finished
        rows, then one batched decode step for the survivors. Returns the
        number of tokens emitted. With speculation on, the whole phase is
        delegated to the SpecDecoder (draft -> one (B, k+1) verify ->
        accept/rollback)."""
        if self._spec is not None:
            return self._spec.decode_tick()
        entries = self.sched.decode_entries()
        if not entries:
            return 0
        toks, ok = self._sample(
            self._logits, self._temp, self._top_k, self._top_p,
            self._seed, self._step,
        )
        toks, ok = np.asarray(toks), np.asarray(ok)
        in_toks = np.full((self.batch, 1), self.pad_id, np.int32)
        in_pos = np.full((self.batch,), -1, np.int32)
        emitted = 0
        survivors = []
        for e in entries:
            if not ok[e.slot]:
                # Poisoned logits (NaN/inf escaped the model): retire the
                # row instead of committing a garbage token — the other
                # rows' streams are untouched.
                self._abort_entry(e, "error")
                self.nonfinite_retired += 1
                continue
            tok = int(toks[e.slot])
            self._step[e.slot] += 1
            emitted += 1
            finished = self.sched.record_token(e, tok)
            if self.tracer is not None:
                self.tracer.span(e.req, SPAN_DECODE_TICK, token=tok)
            if finished:
                self._retire_entry(e)
            elif not self.backend.ensure_decode_block(e.slot, e.pos):
                self._preempt(e)
            else:
                in_toks[e.slot, 0] = tok
                in_pos[e.slot] = e.pos
                survivors.append(e)
        if survivors:
            logits = self.backend.decode(
                self.params, jnp.asarray(in_toks), jnp.asarray(in_pos)
            )
            self._logits = logits[:, 0].astype(jnp.float32)
            self.decode_steps += 1
            for e in survivors:
                e.pos += 1
        return emitted

    def step(self) -> int:
        """One engine tick: expire deadlines, admit, (maybe) one prefill
        chunk, one batched sample+decode pass. Returns tokens emitted
        this tick. With observability on, the tick also emits
        kernel-fallback spans (detected by counter delta — the fallback
        happens inside the backend) and appends one flight-recorder
        record."""
        t0 = time.perf_counter() if self.recorder is not None else 0.0
        self._expire_deadlines()
        if self.telemetry is not None:
            self.telemetry.begin_tick()
        admitted = self._admit()
        prefilled = self._do_prefill_chunk()
        if self.telemetry is not None:
            self.telemetry.drain(self.backend)
        emitted = self._do_decode()
        if self.telemetry is not None:
            self.telemetry.drain(self.backend)
        self.ticks += 1
        kfb = getattr(self.backend, "kernel_fallbacks", 0)
        if kfb != self._kfb_seen:
            self._kfb_seen = kfb
            if self.tracer is not None:
                for e in self.sched.live.values():
                    self.tracer.span(e.req, SPAN_KERNEL_FALLBACK)
        if self.recorder is not None:
            self.recorder.record({
                "tick": self.ticks,
                "t": t0,
                "wall_s": round(time.perf_counter() - t0, 6),
                "queued": len(self.sched.queue),
                "live": len(self.sched.live),
                "decode_rows": len(self.sched.decode_entries()),
                "admitted": admitted,
                "prefilled": int(prefilled),
                "emitted": emitted,
                "kernel_fallbacks": kfb,
                "jit_cache_sizes": self.jit_cache_sizes(),
                "programs": {name: t.take_tick()
                             for name, t in self._timers.items()},
                **self.backend.occupancy(),
                **({"telemetry": dict(self.telemetry.tick)}
                   if self.telemetry is not None and self.telemetry.tick
                   else {}),
            })
        return emitted

    def run(self) -> int:
        """Drain queue + live rows to completion; returns total decode
        model calls (the old wave-engine return contract)."""
        while self.sched.pending():
            self.step()
        return self.decode_steps

    # -- introspection -----------------------------------------------------

    def jit_cache_sizes(self) -> tuple:
        """Compiled-signature counts of every serving program (backend
        programs + the sampler + the speculative accept) — frozen after
        warmup means zero recompiles under churn."""
        sizes = self.backend.jit_cache_sizes() + (self._sample._cache_size(),)
        if self._spec is not None:
            sizes += (self._spec._accept._cache_size(),)
        return sizes

    def config_info(self) -> dict:
        """Frozen engine configuration, as flat str/int values — the
        exporter (serve/exporter.py) renders it as the
        ``engine_info{...} 1`` gauge so a scrape identifies exactly what
        was serving; the bench stores it in BENCH_serve.json."""
        info = {
            "arch": str(self.cfg.name),
            "backend": ("paged" if isinstance(self.backend, PagedBackend)
                        else "contiguous"),
            "max_batch": self.batch,
            "max_len": self.max_len,
            "prefill_chunk": self.sched.prefill_chunk,
            "spec": "on" if self._spec is not None else "off",
            "trace": "on" if self.tracer is not None else "off",
        }
        if isinstance(self.backend, PagedBackend):
            be = self.backend
            info.update(
                block_size=be.block_size,
                num_blocks=be.num_blocks,
                use_kernel="on" if be.use_kernel else "off",
                prefix_cache="on" if be.prefix is not None else "off",
                cache_generated="on" if be.cache_generated else "off",
            )
        if self._spec is not None:
            info["spec_k"] = self._spec.k
        return info

    def robustness_stats(self) -> dict:
        """Degradation/termination counters (serve/metrics.py merges
        these into the server's metric snapshot)."""
        out = {
            "preemptions": self.preemptions,
            "cancellations": self.cancellations,
            "nonfinite_retired": self.nonfinite_retired,
            "deadline_misses_ttft": self.deadline_misses["ttft"],
            "deadline_misses_total": self.deadline_misses["total"],
            "kernel_fallbacks": getattr(self.backend,
                                        "kernel_fallbacks", 0),
        }
        if self._spec is not None:
            out["spec_rows_disabled"] = self._spec.rows_disabled
            out["spec_drafter_errors"] = self._spec.drafter_errors
        return out

    def spec_stats(self) -> Optional[dict]:
        """Speculation counters (None when speculation is off)."""
        if self._spec is None:
            return None
        s = self._spec
        return {
            "verify_calls": s.verify_calls,
            "drafted": s.drafted,
            "accepted": s.accepted,
            "tokens_emitted": s.tokens_emitted,
            "acceptance_rate": s.acceptance_rate,
            "calls_per_token": s.calls_per_token(),
        }

    def peak_cache_bytes(self) -> int:
        return self.backend.peak_cache_bytes()

    def telemetry_snapshot(self) -> dict:
        """Latest flat model-interior stats per phase (empty when
        telemetry is off): ``{"decode": {"moe_l2_dispatch_entropy": ...,
        "logits_max_abs_logit": ...}, "prefill": {...}}``."""
        if self.telemetry is None:
            return {}
        return {phase: dict(flat)
                for phase, flat in self.telemetry.latest.items()}

    def program_efficiency(self) -> dict:
        """Roofline-vs-measured attribution: predicted lower-bound
        seconds per program (roofline/analysis.py
        ``serving_program_bounds``) over the ``ProgramTimer`` measured
        mean wall time — the ``repro_serve_program_efficiency`` gauge.
        1.0 means the program runs at the roofline bound on the target;
        on other hosts it is an attribution number, not a grade. Empty
        until a program has run (needs telemetry or a flight recorder
        for the timers to exist)."""
        from ..roofline.analysis import serving_program_bounds

        if not self._timers:
            return {}
        lanes = (self._spec.k + 1) if self._spec is not None else 1
        bounds = serving_program_bounds(
            self.cfg, self.batch, self.sched.prefill_chunk, lanes)
        out = {}
        for name, timer in self._timers.items():
            if name not in bounds or timer.calls == 0:
                continue
            measured = timer.total_s / timer.calls
            out[name] = bounds[name] / measured if measured > 0 else 0.0
        return out


# ---------------------------------------------------------------------------
# Wave-synchronous baseline (benchmarks)
# ---------------------------------------------------------------------------


class WaveEngine:
    """The pre-continuous engine: a wave of requests admits together,
    decodes in lockstep, and every slot is held until the LAST row of the
    wave finishes. Kept as the baseline bench_serve.py measures against."""

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 default_sampling: SamplingParams = GREEDY, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.default_sampling = default_sampling

        def prefill(params, t, p, cache):
            logits, cache, _ = lm_apply(
                params, cfg, t, positions=p, cache=cache,
                mode="prefill", last_only=True,
            )
            return logits, cache

        # Jitted once; still recompiles per distinct padded prompt length —
        # an inherent wave cost the continuous engine's fixed chunks remove.
        self._prefill = jax.jit(prefill, donate_argnums=(3,))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))
        self._sample = jax.jit(sample_tokens)
        self.queue: List[Request] = []
        self.decode_steps = 0

    def peak_cache_bytes(self) -> int:
        # abstract shapes only — don't materialize a pool to measure one
        shapes = jax.eval_shape(
            lambda: init_cache(self.cfg, self.batch, self.max_len)
        )
        return sum(
            int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(shapes)
        )

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _sample_wave(self, wave, logits, step_base):
        sp = [
            (r.sampling if r.sampling is not GREEDY else
             self.default_sampling)
            for r in wave
        ] + [GREEDY] * (self.batch - len(wave))
        toks = self._sample(
            logits[:, -1].astype(jnp.float32),
            np.array([p.temperature for p in sp], np.float32),
            np.array([p.top_k for p in sp], np.int32),
            np.array([p.top_p for p in sp], np.float32),
            np.array([p.seed for p in sp], np.int32),
            np.full((self.batch,), step_base, np.int32),
        )
        return np.asarray(toks)

    def _run_wave(self, wave: List[Request]) -> int:
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((self.batch, plen), self.pad_id, np.int32)
        poss = np.full((self.batch, plen), -1, np.int32)
        for i, r in enumerate(wave):
            # right-align so the last prompt token sits at index plen-1
            toks[i, plen - len(r.prompt):] = r.prompt
            poss[i, plen - len(r.prompt):] = np.arange(len(r.prompt))
        cache = init_cache(self.cfg, self.batch, self.max_len)
        logits, cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(poss), cache
        )
        nxt = self._sample_wave(wave, logits, 0)
        now = time.perf_counter()
        for i, r in enumerate(wave):
            r.out.append(int(nxt[i]))
            r.t_first_token = now
        steps = 0
        budget = max(r.max_new_tokens for r in wave)
        pos = np.array([len(r.prompt) for r in wave]
                       + [0] * (self.batch - len(wave)), np.int32)
        cur = nxt[:, None]
        while steps < budget - 1 and int(pos.max()) < self.max_len:
            logits, cache = self._decode(
                self.params, jnp.asarray(cur), jnp.asarray(pos), cache
            )
            self.decode_steps += 1
            nxt = self._sample_wave(wave, logits, steps + 1)
            now = time.perf_counter()
            for i, r in enumerate(wave):
                if not r.done and len(r.out) < r.max_new_tokens:
                    tok = int(nxt[i])
                    r.out.append(tok)
                    if self.eos_id is not None and tok == self.eos_id:
                        r.done = True
                        r.finish_reason = "eos"
                        r.t_done = now
            cur = nxt[:, None]
            pos += 1
            steps += 1
        now = time.perf_counter()
        for r in wave:
            if not r.done:
                r.done = True
                r.finish_reason = (
                    "length" if len(r.out) >= r.max_new_tokens
                    else "cache_ceiling"
                )
                r.t_done = now
        return steps + 1

    def run(self) -> int:
        total = 0
        while self.queue:
            wave = self.queue[: self.batch]
            self.queue = self.queue[self.batch:]
            total += self._run_wave(wave)
        return total
