from .engine import (  # noqa: F401
    Request,
    ServeEngine,
    make_decode_step,
    make_prefill_step,
    sample_greedy,
    sample_temperature,
)
