"""Serving subsystem: continuous batching over pluggable cache backends.

Layering (docs/serving.md has the full design):
  cache_pool    — CacheBackend interface + contiguous slot-row backend
  block_manager — paged backend: KV token blocks, refcounts/COW, tables
  prefix_cache  — radix tree mapping token prefixes to shared block chains
  programs      — the jitted device programs (contiguous + paged)
  sampling      — batched per-request sampler suite (greedy/temp/top-k/top-p)
                  + the speculative accept/resample step
  scheduler     — host-side admission queue + slot state machine
  spec_decode   — speculative decoding: n-gram self-drafting + (B, k+1)
                  verify + rejection-sampling accept with exact rollback
  engine        — ServeEngine (continuous) / WaveEngine (lockstep baseline)
  server        — AsyncServer: asyncio front end (deadlines, cancellation,
                  load shedding, retry-with-backoff, token streaming)
  metrics       — ServeMetrics counter/histogram surface + stuck-step Watchdog
  tracing       — per-request span timelines + engine tick flight recorder
  exporter      — Prometheus text-format rendering (/metrics) + strict parser
  telemetry     — model-interior telemetry consumers: flatten/aggregate the
                  device-side routing-health + numerics pytrees, and the
                  batch-variance probe (docs/observability.md)
  faults        — seeded fault injection + chaos harness (CI chaos-smoke)
"""
from .block_manager import (  # noqa: F401
    BlockManager,
    PagedBackend,
    init_paged_cache,
)
from .cache_pool import (  # noqa: F401
    CacheBackend,
    CachePool,
    ContiguousBackend,
    clear_slot,
    pool_row,
    pool_write_row,
)
from .engine import (  # noqa: F401
    ServeEngine,
    WaveEngine,
    make_decode_step,
    make_prefill_chunk_step,
    make_prefill_step,
)
from .exporter import (  # noqa: F401
    parse_prometheus,
    render_prometheus,
)
from .faults import (  # noqa: F401
    FaultInjector,
    FlakyDrafter,
    GarbageDrafter,
    assert_leak_free,
    pool_snapshot,
    run_chaos,
)
from .metrics import (  # noqa: F401
    Histogram,
    ServeMetrics,
    Watchdog,
    collect_engine_metrics,
)
from .prefix_cache import RadixPrefixCache  # noqa: F401
from .programs import (  # noqa: F401
    make_decode_step_paged,
    make_prefill_chunk_paged,
)
from .sampling import (  # noqa: F401
    GREEDY,
    SamplingParams,
    sample_greedy,
    sample_temperature,
    sample_tokens,
    spec_accept_tokens,
    stack_params,
)
from .scheduler import (  # noqa: F401
    QueueFull,
    Request,
    Scheduler,
    SlotEntry,
)
from .server import (  # noqa: F401
    AsyncServer,
    ServerConfig,
    ShedError,
)
from .spec_decode import (  # noqa: F401
    Drafter,
    NgramDrafter,
    SpecConfig,
    SpecDecoder,
)
from .telemetry import (  # noqa: F401
    TelemetryAggregator,
    batch_variance_probe,
    flatten_telemetry,
    telemetry_rows,
)
from .tracing import (  # noqa: F401
    FlightRecorder,
    ProgramTimer,
    Tracer,
    render_timeline,
    timeline,
    validate_timeline,
)
