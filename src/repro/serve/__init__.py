"""Serving subsystem: continuous batching over a per-row KV/SSM cache pool.

Layering (docs/serving.md has the full design):
  cache_pool — slot allocator over one fixed-shape device cache
  sampling   — batched per-request sampler suite (greedy/temp/top-k/top-p)
  scheduler  — host-side admission queue + slot state machine
  engine     — ServeEngine (continuous) / WaveEngine (lockstep baseline)
"""
from .cache_pool import CachePool, clear_slot, pool_row, pool_write_row  # noqa: F401
from .engine import (  # noqa: F401
    ServeEngine,
    WaveEngine,
    make_decode_step,
    make_prefill_chunk_step,
    make_prefill_step,
)
from .sampling import (  # noqa: F401
    GREEDY,
    SamplingParams,
    sample_greedy,
    sample_temperature,
    sample_tokens,
    stack_params,
)
from .scheduler import Request, Scheduler, SlotEntry  # noqa: F401
