"""Paged serving memory: fixed-size KV token blocks + per-request tables.

``BlockManager`` is pure host-side bookkeeping (testable without jax):
physical blocks carry refcounts so a block can back several requests at
once — the radix prefix cache (serve/prefix_cache.py) and request forks
share blocks instead of copying them, and a write to a shared block goes
through copy-on-write.

``PagedBackend`` owns the device side: per-layer block pools
(``init_paged_kv_cache`` — leading dim indexes physical blocks, not
rows), slot-indexed SSM state, the per-slot block tables, and the jitted
paged prefill/decode/clear/copy programs. It implements the same
``CacheBackend`` interface as the contiguous pool (serve/cache_pool.py),
so ``ServeEngine`` drives either interchangeably and the contiguous
engine stays the bit-exact correctness oracle.

Memory math (docs/serving.md): the contiguous pool is
``num_slots x max_len`` token positions whatever the traffic; the paged
pool holds ``num_blocks x block_size`` and a request pins only
``ceil(len / block_size)`` blocks, so peak usage tracks tokens actually
resident — the high-water mark is tracked and reported per engine.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..layers.attention import init_paged_kv_cache
from ..layers.ssm import init_ssm_cache
from .cache_pool import CacheBackend
from .prefix_cache import RadixPrefixCache

# Fixed width of the jitted block clear/copy programs: ids are padded to a
# multiple of this with out-of-range sentinels (dropped scatters), so any
# allocation count runs through one compiled signature.
_ID_BATCH = 8


class BlockManager:
    """Free-list allocator with refcounts over `num_blocks` physical
    blocks. Block 0 is the reserved NULL block (never allocated; its pool
    `pos` stays -1, so table entries of 0 mean "nothing here").

    Refcount protocol: alloc() returns blocks at refcount 1 (the owning
    request). Sharing — a prefix-cache node adopting a block, or a fork
    duplicating a table — increfs. decref() frees at zero. A writer must
    hold the ONLY reference; `needs_cow` says whether a write has to
    copy first.
    """

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least the null block + one real"
        self.num_blocks = num_blocks
        self.ref = np.zeros((num_blocks,), np.int32)
        self.ref[0] = 1  # null block: pinned forever
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.high_water = 0  # max blocks simultaneously allocated

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> List[int]:
        assert self.can_alloc(n), f"alloc({n}) with {len(self._free)} free"
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self.ref[b] == 0
            self.ref[b] = 1
        self.high_water = max(self.high_water, self.num_used)
        return out

    def incref(self, block: int):
        assert block != 0 and self.ref[block] > 0
        self.ref[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True if the block was freed."""
        assert block != 0 and self.ref[block] > 0, f"bad decref({block})"
        self.ref[block] -= 1
        if self.ref[block] == 0:
            self._free.append(block)
            return True
        return False

    def needs_cow(self, block: int) -> bool:
        return self.ref[block] > 1

    def fork_table(self, table: Sequence[int]) -> List[int]:
        """Share every block of a table with a second owner (copy-on-write
        fork): increfs each real block, returns the copied table."""
        out = list(table)
        for b in out:
            if b != 0:
                self.incref(b)
        return out


def init_paged_cache(cfg, num_blocks: int, block_size: int, num_slots: int,
                     dtype=jnp.bfloat16):
    """Per-layer cache list for the paged backend: attention layers get a
    (num_blocks, block_size, ...) block pool SHARED by all rows; SSM
    layers keep (num_slots, ...) per-row recurrent state (constant-size —
    nothing to page)."""
    caches = []
    for _ in range(cfg.num_layers):
        c = {}
        if cfg.has_attention():
            c["attn"] = init_paged_kv_cache(cfg, num_blocks, block_size,
                                            dtype)
        if cfg.has_ssm():
            c["ssm"] = init_ssm_cache(cfg, num_slots, dtype)
        caches.append(c)
    return caches


def _pad_ids(ids: Sequence[int], sentinel: int) -> np.ndarray:
    """Pad to the next _ID_BATCH multiple with out-of-range ids."""
    n = max(_ID_BATCH, -(-len(ids) // _ID_BATCH) * _ID_BATCH)
    out = np.full((n,), sentinel, np.int32)
    out[: len(ids)] = ids
    return out


class PagedBackend(CacheBackend):
    """Paged cache backend: block-table addressing + radix prefix cache.

    Admission needs a free slot (decode batch row + SSM state) AND enough
    free blocks for the uncached part of the prompt — NOT a whole
    max_len reservation. Decode allocates one block at a time as a row
    crosses block boundaries; when the free list runs dry the prefix
    cache evicts LRU-first, and if that is not enough the engine preempts
    the row (requeues it) rather than corrupting memory.
    """

    def __init__(self, cfg, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True, use_kernel: bool = True,
                 cache_generated: bool = False, telemetry: bool = False):
        from .programs import (
            clear_blocks_program,
            clear_ssm_slot_program,
            copy_blocks_program,
            invalidate_positions_paged_program,
            make_decode_step_paged,
            make_prefill_chunk_paged,
            make_verify_step_paged,
        )

        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.block_size = block_size
        self.blocks_per_row = -(-max_len // block_size)
        if num_blocks is None:
            # capacity parity with the contiguous pool by default; callers
            # size it down to get prompt-proportional memory
            num_blocks = num_slots * self.blocks_per_row + 1
        self.num_blocks = num_blocks
        self.cache = init_paged_cache(cfg, num_blocks, block_size,
                                      num_slots, dtype)
        self.mgr = BlockManager(num_blocks)
        # Prefix reuse splices cached KV under a *new* request, which is
        # only sound when all cross-token state lives in the cache —
        # recurrent SSM state is not block-addressable, so hybrid/SSM
        # archs run paged but uncached.
        self.prefix: Optional[RadixPrefixCache] = (
            RadixPrefixCache(block_size)
            if prefix_cache and not cfg.has_ssm() else None
        )
        # cache_finished() publishes a retired request's prompt+OUTPUT
        # block chain into the radix tree, so a follow-up request whose
        # prompt extends a completed conversation gets prefix hits past
        # the original prompt boundary (multi-turn reuse). Opt-in: the
        # tree then retains generation blocks until LRU eviction, which
        # trades pool headroom for hits.
        self.cache_generated = cache_generated and self.prefix is not None
        self.tables = np.zeros((num_slots, self.blocks_per_row), np.int32)
        self._tables_dev = None  # rebuilt lazily when tables change
        self._free_slots: List[int] = list(range(num_slots - 1, -1, -1))
        # Peak blocks pinned by LIVE request tables. Unlike
        # mgr.high_water (pool usage, which the radix tree's retained-
        # but-evictable blocks push toward capacity in any sustained
        # run), this measures actual request footprint — the number the
        # memory-proportionality claim is about.
        self.live_block_hw = 0

        # Decode runs the Pallas paged-attention kernel by default (tiles
        # streamed from the pool in place); use_kernel=False keeps the jnp
        # row-view gather — the bit-exact oracle the kernel is tested
        # against. Chunked prefill always takes the gather path (S > 1).
        # A kernel failure at dispatch degrades PERMANENTLY to the gather
        # oracle (`_kernel_fallback`) instead of taking serving down;
        # `kernel_fallbacks` counts the degradations for the metrics
        # surface.
        self.use_kernel = use_kernel
        self.kernel_fallbacks = 0
        # Telemetry variants of the programs (see serve/programs.py):
        # every call stashes its telemetry pytree on `last_telemetry` as
        # (phase, pytree) for the engine to drain.
        self.telemetry = telemetry
        self.last_telemetry = None
        self._prefill_chunk = jax.jit(
            make_prefill_chunk_paged(cfg, telemetry=telemetry),
            donate_argnums=(1, 2)
        )
        self._decode = jax.jit(
            make_decode_step_paged(cfg, use_kernel=use_kernel,
                                   telemetry=telemetry),
            donate_argnums=(4,),
        )
        # Speculative-decoding programs (compiled lazily at first use).
        self._verify = jax.jit(
            make_verify_step_paged(cfg, use_kernel=use_kernel,
                                   telemetry=telemetry),
            donate_argnums=(4,),
        )
        self._invalidate = jax.jit(
            invalidate_positions_paged_program, donate_argnums=(0,)
        )
        self._clear_blocks = jax.jit(
            clear_blocks_program, donate_argnums=(0,)
        )
        self._copy_blocks = jax.jit(copy_blocks_program, donate_argnums=(0,))
        self._clear_ssm = (
            jax.jit(clear_ssm_slot_program, donate_argnums=(0,))
            if cfg.has_ssm() else None
        )

    # -- CacheBackend ------------------------------------------------------

    @property
    def num_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def max_chunk(self) -> int:
        # One chunk's positions are distinct, so distinct (block, offset)
        # slots — no scatter-order hazard at any chunk size.
        return self.max_len

    def accepts(self, prompt_len: int, max_new: int) -> bool:
        worst = -(-(prompt_len + max_new) // self.block_size)
        return worst <= self.num_blocks - 1

    def try_admit(self, req) -> Optional[Tuple[int, int]]:
        if not self._free_slots:
            return None
        prompt = req.prompt
        cached: List[int] = []
        if self.prefix is not None and not req.no_prefix_cache:
            cached = self.prefix.match(prompt)
        cached_len = len(cached) * self.block_size
        # blocks covering the uncached prompt tail plus the first decode
        # token. Clamp at max_len: a prompt that fills the window exactly
        # (max_new_tokens == 0) retires on cache_full before any decode
        # write, so position max_len never needs a block — and without
        # the clamp n_logical would exceed blocks_per_row.
        n_logical = -(-min(len(prompt) + 1, self.max_len)
                      // self.block_size)
        need = n_logical - len(cached)
        # pin the matched chain before eviction can run
        for b in cached:
            self.mgr.incref(b)
        if not self._reserve(need):
            for b in cached:
                self.mgr.decref(b)
            return None
        fresh = self.mgr.alloc(need)
        self._invalidate_blocks(fresh)
        slot = self._free_slots.pop()
        if self._clear_ssm is not None:
            self.cache = self._clear_ssm(self.cache, jnp.int32(slot))
        row = self.tables[slot]
        row[:] = 0
        row[: len(cached)] = cached
        row[len(cached): n_logical] = fresh
        self._tables_dev = None
        self._touch_live_hw()
        if self.prefix is not None and not req.no_prefix_cache:
            self.prefix.record_lookup(len(cached))
        return slot, cached_len

    def prefill_chunk(self, params, buf, slot: int, toks, poss):
        table = jnp.asarray(self.tables[slot: slot + 1])
        out = self._prefill_chunk(
            params, self.cache, buf, jnp.int32(slot), table,
            jnp.asarray([toks], jnp.int32), jnp.asarray([poss], jnp.int32),
        )
        self.cache, buf = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("prefill", out[2])
        return buf

    def prefill_finished(self, entry):
        """Publish the request's full prompt blocks into the radix tree
        the moment prefill completes — later requests with the same
        system prompt share them immediately, not at retirement."""
        if self.prefix is None:
            return
        prompt = entry.req.prompt
        row = self.tables[entry.slot]
        n_full = len(prompt) // self.block_size
        self.prefix.insert(prompt[: n_full * self.block_size],
                           list(row[:n_full]), self.mgr)

    def ensure_decode_block(self, slot: int, pos: int) -> bool:
        """Make position `pos` writable for `slot`: allocate the logical
        block if the table has none (evicting prefix LRU under pressure),
        copy-on-write if it is shared. False = out of memory (preempt)."""
        return self._ensure_logical_block(slot, pos // self.block_size)

    def reserve_burst(self, slot: int, start: int, n: int) -> int:
        """Make positions [start, start+n) writable for a speculative
        burst: secure (alloc/COW) every logical block in range, in order,
        evicting prefix LRU under pressure. Returns the number of leading
        positions covered — a partial reservation shrinks the burst
        rather than failing it, and 0 means even the pending token's
        position could not be secured (the engine preempts)."""
        bs = self.block_size
        end = min(start + n, self.max_len)
        covered = 0
        for lb in range(start // bs, -(-end // bs)):
            if not self._ensure_logical_block(slot, lb):
                break
            covered = min(end, (lb + 1) * bs) - start
        return max(0, min(covered, n))

    def rollback_burst(self, slot: int, next_pos: int):
        """Un-reserve blocks that exist only to hold rejected draft
        positions beyond ``next_pos`` (the row's next write position).
        Afterwards the table and refcounts are exactly the
        never-having-drafted state: blocks cover positions <= next_pos,
        the same footprint `ensure_decode_block(slot, next_pos)` leaves
        on the non-speculative path."""
        row = self.tables[slot]
        changed = False
        for lb in range(next_pos // self.block_size + 1,
                        self.blocks_per_row):
            blk = int(row[lb])
            if blk != 0:
                self.mgr.decref(blk)
                row[lb] = 0
                changed = True
        if changed:
            self._tables_dev = None

    def _ensure_logical_block(self, slot: int, lb: int) -> bool:
        blk = int(self.tables[slot, lb])
        if blk == 0:
            if not self._reserve(1):
                return False
            (fresh,) = self.mgr.alloc(1)
            self._invalidate_blocks([fresh])
            self.tables[slot, lb] = fresh
            self._tables_dev = None
            self._touch_live_hw()
        elif self.mgr.needs_cow(blk):
            if not self._reserve(1):
                return False
            (fresh,) = self.mgr.alloc(1)
            self.copy_blocks([blk], [fresh])
            self.mgr.decref(blk)
            self.tables[slot, lb] = fresh
            self._tables_dev = None
            self._touch_live_hw()  # divergence: one more unique block
        return True

    def _kernel_fallback(self):
        """Graceful degradation: a Pallas kernel failure (compile or
        dispatch) rebuilds BOTH multi-token programs on the jnp gather
        oracle and turns the kernel off for the backend's lifetime. The
        gather path is bit-exact, so serving continues unchanged — only
        the decode HBM saving is lost. Safe to invoke at trace/compile
        failure time: the cache pytree is only replaced on a successful
        call, and buffer donation cannot have consumed it before the
        program ever ran."""
        from .programs import make_decode_step_paged, make_verify_step_paged

        assert self.use_kernel, "fallback with the kernel already off"
        self.use_kernel = False
        self.kernel_fallbacks += 1
        # the rebuilt programs must keep the telemetry flag: losing it
        # would change the program arity mid-serve
        self._decode = jax.jit(
            make_decode_step_paged(self.cfg, use_kernel=False,
                                   telemetry=self.telemetry),
            donate_argnums=(4,),
        )
        self._verify = jax.jit(
            make_verify_step_paged(self.cfg, use_kernel=False,
                                   telemetry=self.telemetry),
            donate_argnums=(4,),
        )

    def decode(self, params, toks, pos):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.tables)
        try:
            out = self._decode(
                params, toks, pos, self._tables_dev, self.cache
            )
        except Exception:
            if not self.use_kernel:
                raise
            self._kernel_fallback()
            out = self._decode(
                params, toks, pos, self._tables_dev, self.cache
            )
        logits, self.cache = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("decode", out[2])
        return logits

    def verify(self, params, toks, poss):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.tables)
        try:
            out = self._verify(
                params, toks, poss, self._tables_dev, self.cache
            )
        except Exception:
            if not self.use_kernel:
                raise
            self._kernel_fallback()
            out = self._verify(
                params, toks, poss, self._tables_dev, self.cache
            )
        logits, self.cache = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("verify", out[2])
        return logits

    def invalidate_positions(self, positions):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.tables)
        self.cache = self._invalidate(
            self.cache, positions, self._tables_dev
        )

    def cache_finished(self, entry):
        """Publish the retiring request's prompt+output chain into the
        radix tree (``cache_generated``): a repeat or multi-turn
        continuation then gets prefix hits past the original prompt
        boundary. Only full blocks are insertable, and the last emitted
        token is excluded — it was sampled but never fed, so its KV slot
        is unwritten (on every path: EOS, budget, ceiling, speculative
        truncation)."""
        if not self.cache_generated or entry.req.no_prefix_cache:
            return
        toks = list(entry.req.prompt) + list(entry.req.out[:-1])
        n_full = len(toks) // self.block_size
        if n_full == 0:
            return
        row = self.tables[entry.slot]
        self.prefix.insert(
            toks[: n_full * self.block_size],
            [int(b) for b in row[:n_full]], self.mgr,
        )

    def retire(self, slot: int):
        row = self.tables[slot]
        for b in row:
            if b != 0:
                self.mgr.decref(int(b))
        row[:] = 0
        self._tables_dev = None
        assert slot not in self._free_slots, f"double retire of slot {slot}"
        self._free_slots.append(slot)

    def jit_cache_sizes(self) -> tuple:
        sizes = (self._decode._cache_size(),
                 self._prefill_chunk._cache_size(),
                 self._clear_blocks._cache_size(),
                 self._copy_blocks._cache_size(),
                 self._verify._cache_size(),
                 self._invalidate._cache_size())
        if self._clear_ssm is not None:
            sizes += (self._clear_ssm._cache_size(),)
        return sizes

    def token_capacity(self) -> int:
        return (self.num_blocks - 1) * self.block_size

    def tokens_free(self) -> int:
        """Token positions admission control can still promise: the free
        list plus tree-retained blocks no live table references (those
        are reclaimable via LRU eviction — counting them stops the
        server shedding everything once the radix tree has warmed up to
        pool capacity, which it does in any sustained run)."""
        live = np.unique(self.tables[self.tables != 0]).size
        reclaimable = max(0, self.mgr.num_used - int(live))
        return (self.mgr.num_free + reclaimable) * self.block_size

    def bytes_per_block(self) -> int:
        per = 0
        for layer in self.cache:
            if "attn" in layer:
                for leaf in layer["attn"].values():
                    per += leaf.nbytes // self.num_blocks
        return per

    def ssm_bytes(self) -> int:
        per = 0
        for layer in self.cache:
            if "ssm" in layer:
                per += sum(leaf.nbytes for leaf in
                           jax.tree_util.tree_leaves(layer["ssm"]))
        return per

    def peak_cache_bytes(self) -> int:
        """Peak live-request block footprint x bytes/block (+ the
        constant SSM rows) — what a right-sized pool would have needed
        for the traffic, the number the bench compares against
        num_slots x max_len. Tree-retained (evictable) blocks are
        excluded: they are reclaimable cache, and counting them would
        just report the configured pool size in any sustained run."""
        return self.live_block_hw * self.bytes_per_block() + self.ssm_bytes()

    def occupancy(self) -> dict:
        live = int(np.unique(self.tables[self.tables != 0]).size)
        return {
            "blocks_free": self.mgr.num_free,
            "blocks_used": self.mgr.num_used,
            "blocks_live": live,
            "slots_free": len(self._free_slots),
            "slots_total": self.num_slots,
        }

    def _touch_live_hw(self):
        # unique physical blocks: a prefix-shared block backing several
        # table rows is ONE resident block, not one per row
        used = self.tables[self.tables != 0]
        self.live_block_hw = max(self.live_block_hw,
                                 int(np.unique(used).size))

    # -- internals ---------------------------------------------------------

    def _reserve(self, n: int) -> bool:
        """Ensure `n` free blocks, evicting prefix-cache LRU leaves as
        needed; False if physically impossible right now."""
        while not self.mgr.can_alloc(n):
            if self.prefix is None or not self.prefix.evict_one(self.mgr):
                return False
        return True

    def _invalidate_blocks(self, blocks: List[int]):
        """pos -> -1 for freshly allocated blocks: stale entries from the
        previous owner must not alias the new request's positions (the
        paged analogue of the contiguous pool's acquire-time row clear)."""
        if not blocks:
            return
        ids = _pad_ids(blocks, self.num_blocks)
        for i in range(0, len(ids), _ID_BATCH):
            self.cache = self._clear_blocks(
                self.cache, jnp.asarray(ids[i: i + _ID_BATCH])
            )

    def copy_blocks(self, src: List[int], dst: List[int]):
        """Device copy src[i] -> dst[i] (COW / fork). Fixed-width padded
        batches: zero recompiles whatever the count."""
        assert len(src) == len(dst)
        if not src:
            return
        s = _pad_ids(src, 0)  # src pad: clamped read, dropped by dst pad
        d = _pad_ids(dst, self.num_blocks)
        for i in range(0, len(s), _ID_BATCH):
            self.cache = self._copy_blocks(
                self.cache, jnp.asarray(s[i: i + _ID_BATCH]),
                jnp.asarray(d[i: i + _ID_BATCH]),
            )

    def fork_slot(self, src_slot: int) -> Optional[int]:
        """Fork a live row into a fresh slot sharing ALL its blocks
        (copy-on-write): the clone diverges block-by-block as either row
        writes. Returns the new slot or None (no slot free). SSM state is
        copied by value (it is per-slot, not shared)."""
        if not self._free_slots:
            return None
        slot = self._free_slots.pop()
        self.tables[slot] = self.mgr.fork_table(self.tables[src_slot])
        self._tables_dev = None
        self._touch_live_hw()
        if self._clear_ssm is not None:
            # slot-state copy: roundtrip through host is fine (fork is a
            # control-plane operation, not a per-token one)
            for layer in self.cache:
                if "ssm" in layer:
                    for name, leaf in layer["ssm"].items():
                        layer["ssm"][name] = leaf.at[slot].set(
                            leaf[src_slot]
                        )
        return slot
