"""Slot-based KV/SSM cache pool for continuous batching.

The pool owns ONE device cache pytree of fixed shape (``num_slots`` rows ×
``max_len`` positions, per layer — see ``models.init_cache``) for the whole
engine lifetime; requests borrow a row ("slot") for their residency and
return it the step they finish. Because attention caches store *per-row*
positions, rows are fully independent: admitting or retiring one never
touches another and never changes any jitted shape.

Invariants (tested in tests/test_cache_pool.py):

* A freshly acquired slot is CLEAN: every attention `pos` entry of the row
  is -1 (stale K/V values may remain — they are unreachable, since the
  causal mask admits only entries with pos >= 0 and any new write replaces
  value and pos together) and SSM conv/state rows are zeroed (recurrent
  state has no position mask, so it must be scrubbed).
* Slot clears are a single jitted fixed-shape program (`slot` is a traced
  scalar), so pool churn causes zero recompiles.
* The pool never reallocates: `cache` leaves are replaced functionally by
  the jitted step functions, but shapes/dtypes are immutable.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from ..layers.attention import reset_kv_rows
from ..layers.ssm import reset_ssm_rows
from ..models import init_cache


def clear_slot(cache, slot):
    """Pure function: invalidate row `slot` of every per-layer cache.
    Attention rows get pos=-1; SSM rows are zeroed. Jit-safe (slot may be
    traced)."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "attn" in c:
            c["attn"] = reset_kv_rows(c["attn"], slot)
        if "ssm" in c:
            c["ssm"] = reset_ssm_rows(c["ssm"], slot)
        out.append(c)
    return out


def pool_row(cache, slot):
    """Slice one row (kept as batch dim 1) out of every leaf — the batch-1
    view chunked prefill runs the model over. Jit-safe."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0), cache
    )


def pool_write_row(cache, slot, row):
    """Scatter a batch-1 row pytree back into the pool at `slot`. Jit-safe."""
    return jax.tree_util.tree_map(
        lambda a, r: jax.lax.dynamic_update_slice_in_dim(
            a, r.astype(a.dtype), slot, axis=0
        ),
        cache, row,
    )


class CachePool:
    """Free-list slot allocator over one fixed-shape device cache.

    Slot lifecycle: free -> acquire() [row cleared on device] -> in use by
    exactly one request -> release() -> free. Allocation is LIFO so a hot
    slot (cache rows still resident) is reused first.
    """

    def __init__(self, cfg, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, num_slots, max_len, dtype)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        # Donated: the clear aliases the pool in place (accelerators).
        self._clear = jax.jit(clear_slot, donate_argnums=(0,))
        # Smallest per-layer ring length: chunked prefill must not write a
        # chunk longer than this (a wrap inside one scatter would make
        # duplicate-index write order undefined).
        self.min_ring_len = min(
            (layer["attn"]["pos"].shape[-1] for layer in self.cache
             if "attn" in layer),
            default=max_len,
        )

    @property
    def num_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        """Pop a free slot and clear its row on device; None if exhausted."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.cache = self._clear(self.cache, jnp.int32(slot))
        return slot

    def release(self, slot: int):
        """Return a slot to the free list (host-side only — the row is
        cleared lazily at the next acquire)."""
        assert slot not in self._free, f"double release of slot {slot}"
        self._free.append(slot)


# ---------------------------------------------------------------------------
# Cache backends: the interface ServeEngine drives
# ---------------------------------------------------------------------------


class CacheBackend:
    """Serving-memory backend interface. Two implementations:

    * ``ContiguousBackend`` (below): one `max_len` row per slot — simple,
      bit-exact, the correctness oracle and bench baseline.
    * ``PagedBackend`` (serve/block_manager.py): fixed-size token blocks
      with per-request tables, copy-on-write refcounts, and a radix-tree
      prefix cache.

    The engine only ever calls these methods; every device program behind
    them has one fixed signature (zero recompiles under churn).
    """

    num_free_slots: int
    max_chunk: int
    # model-interior telemetry (docs/observability.md): backends built
    # with telemetry=True stash the latest (phase, device pytree) here
    # after every prefill/decode/verify call; the engine drains it
    telemetry: bool = False
    last_telemetry = None

    def accepts(self, prompt_len: int, max_new: int) -> bool:
        """Can this request EVER fit (submit-time validation)?"""
        raise NotImplementedError

    def try_admit(self, req):
        """Admit `req` if memory allows: returns (slot, cached_len) —
        cached_len > 0 when a prefix-cache hit lets prefill skip the
        first tokens — or None to leave it queued."""
        raise NotImplementedError

    def prefill_chunk(self, params, buf, slot: int, toks, poss):
        """Run one prompt chunk for `slot`; returns the updated logits
        buffer (cache updates stay inside the backend)."""
        raise NotImplementedError

    def prefill_finished(self, entry):
        """Hook fired when a request's last prompt chunk has run."""

    def ensure_decode_block(self, slot: int, pos: int) -> bool:
        """Guarantee position `pos` of `slot` is writable before a decode
        step; False means out of memory (the engine preempts the row)."""
        return True

    def decode(self, params, toks, pos):
        """One batched decode step over all slots; returns logits."""
        raise NotImplementedError

    # -- speculative decoding (serve/spec_decode.py) -----------------------

    def verify(self, params, toks, poss):
        """One batched multi-token verify step: toks/poss are (B, k+1)
        with lane 0 = each row's pending token; returns (B, k+1, V)
        logits. Lanes with position -1 are exact no-ops."""
        raise NotImplementedError

    def reserve_burst(self, slot: int, start: int, n: int) -> int:
        """Make positions [start, start+n) of `slot` writable for a
        speculative burst; returns how many leading positions are covered
        (0 = out of memory even for the pending token — preempt). The
        contiguous pool reserves max_len rows up front, so every
        in-range position is always writable."""
        return n

    def rollback_burst(self, slot: int, next_pos: int):
        """Undo burst-only reservations after acceptance: release memory
        that exists purely to hold positions > `next_pos` (the row's next
        write position). No-op on the contiguous pool."""

    def invalidate_positions(self, positions):
        """pos -> -1 for a (B, k+1) batch of absolute positions (-1 lanes
        drop): scrubs rejected draft lanes so the cache state equals
        never having drafted."""
        raise NotImplementedError

    def cache_finished(self, entry):
        """Hook fired at normal retirement (not preemption), before the
        slot is released — the paged backend publishes generated-token
        blocks into the radix tree here when ``cache_generated`` is on."""

    def retire(self, slot: int):
        """Release every resource `slot` holds."""
        raise NotImplementedError

    def token_capacity(self) -> int:
        """Total token positions the pool can ever hold (admission-
        control budget denominator for serve/server.py load shedding)."""
        raise NotImplementedError

    def tokens_free(self) -> int:
        """Token positions not currently promised to live work (includes
        reclaimable prefix-cache blocks on the paged backend)."""
        raise NotImplementedError

    def jit_cache_sizes(self) -> tuple:
        """Compiled-signature counts of the backend's device programs
        (frozen after warmup == zero recompiles)."""
        raise NotImplementedError

    def peak_cache_bytes(self) -> int:
        """High-water cache memory this backend actually needed."""
        raise NotImplementedError

    def occupancy(self) -> dict:
        """Host-side memory occupancy for the flight recorder (one dict
        per engine tick — must be cheap and jax-free)."""
        return {}


class ContiguousBackend(CacheBackend):
    """`CachePool` behind the CacheBackend interface: admission == a free
    slot, memory == num_slots x max_len whatever the traffic.

    ``telemetry=True`` builds the telemetry variant of each program
    (serve/programs.py): every prefill/decode/verify call additionally
    stashes its telemetry pytree on ``self.last_telemetry`` as
    ``(phase, pytree)`` for the engine to drain — method signatures and
    returned logits are unchanged."""

    def __init__(self, cfg, num_slots: int, max_len: int,
                 dtype=jnp.bfloat16, telemetry: bool = False):
        from .programs import (
            invalidate_positions_program,
            make_decode_step,
            make_prefill_chunk_step,
            make_verify_step,
        )

        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.telemetry = telemetry
        self.last_telemetry = None  # (phase, device pytree) | None
        self.pool = CachePool(cfg, num_slots, max_len, dtype)
        # Donate the cache (and logits buffer) so XLA aliases them in
        # place instead of materializing a second full pool every tick
        # (no-op on CPU, which lacks donation — a one-time warning).
        self._prefill_chunk = jax.jit(
            make_prefill_chunk_step(cfg, telemetry=telemetry),
            donate_argnums=(1, 2)
        )
        self._decode = jax.jit(make_decode_step(cfg, telemetry=telemetry),
                               donate_argnums=(3,))
        # Speculative-decoding programs: compiled lazily at first use, so
        # non-speculative engines never pay for them (their jit caches
        # stay at 0 and the zero-recompile accounting still holds).
        self._verify = jax.jit(make_verify_step(cfg, telemetry=telemetry),
                               donate_argnums=(3,))
        self._invalidate = jax.jit(
            invalidate_positions_program, donate_argnums=(0,)
        )

    @property
    def num_free_slots(self) -> int:
        return self.pool.num_free

    @property
    def max_chunk(self) -> int:
        return self.pool.min_ring_len

    def accepts(self, prompt_len: int, max_new: int) -> bool:
        return prompt_len + max_new <= self.max_len

    def try_admit(self, req):
        slot = self.pool.acquire()
        return None if slot is None else (slot, 0)

    def prefill_chunk(self, params, buf, slot, toks, poss):
        out = self._prefill_chunk(
            params, self.pool.cache, buf, jnp.int32(slot),
            jnp.asarray([toks], jnp.int32), jnp.asarray([poss], jnp.int32),
        )
        self.pool.cache, buf = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("prefill", out[2])
        return buf

    def decode(self, params, toks, pos):
        out = self._decode(params, toks, pos, self.pool.cache)
        logits, self.pool.cache = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("decode", out[2])
        return logits

    def verify(self, params, toks, poss):
        out = self._verify(params, toks, poss, self.pool.cache)
        logits, self.pool.cache = out[0], out[1]
        if self.telemetry:
            self.last_telemetry = ("verify", out[2])
        return logits

    def invalidate_positions(self, positions):
        self.pool.cache = self._invalidate(self.pool.cache, positions)

    def retire(self, slot: int):
        self.pool.release(slot)

    def token_capacity(self) -> int:
        return self.num_slots * self.max_len

    def tokens_free(self) -> int:
        return self.pool.num_free * self.max_len

    def jit_cache_sizes(self) -> tuple:
        return (self._decode._cache_size(),
                self._prefill_chunk._cache_size(),
                self.pool._clear._cache_size(),
                self._verify._cache_size(),
                self._invalidate._cache_size())

    def peak_cache_bytes(self) -> int:
        return sum(leaf.nbytes
                   for leaf in jax.tree_util.tree_leaves(self.pool.cache))

    def occupancy(self) -> dict:
        return {"slots_free": self.pool.num_free,
                "slots_total": self.num_slots}
