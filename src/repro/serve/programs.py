"""Jitted device programs the serving engines run.

Each factory closes over a config and returns a pure function with ONE
fixed signature — request churn changes values (slot ids, positions,
block tables), never shapes, so each program compiles exactly once.

Contiguous programs address the cache as (num_slots, max_len) rows
(serve/cache_pool.py); paged programs address a (num_blocks, block_size)
block pool through per-row block tables (serve/block_manager.py). The
attention cache is per-layer "attn" entries; SSM recurrent state stays
slot-indexed in both layouts (it is constant-size per row — there is
nothing to page).

Every factory takes a STATIC ``telemetry`` flag. ``telemetry=True``
builds a program whose jaxpr additionally emits the ``lm_apply``
telemetry pytree (fixed-shape stop_gradient'd scalars: per-layer
routing health + logit numerics) as a trailing output — the tokens the
program produces are bit-identical to the ``telemetry=False`` build,
and because the flag is baked at build time it can never trigger a
recompile at serve time.

Every program here runs ``lm_apply`` in a serving mode ("prefill" /
"decode"), which makes MoE routing a PURE PER-ROW FUNCTION
(core/sparse_moe.py; Soft MoE is per-row by construction): a row's
outputs are identical whether it is served solo or co-batched, whether
its prompt arrived whole or in chunks, and whether its tokens rode a
(B, 1) decode step or a (B, k+1) speculative verify lane. The batch-
variance probe (serve/telemetry.py) and the chunked-prefill/spec parity
tests are the enforcement of this contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers.attention import (
    copy_kv_blocks,
    invalidate_kv_positions,
    invalidate_paged_positions,
    reset_block_pos,
)
from ..layers.ssm import reset_ssm_rows
from ..models import lm_apply
from .cache_pool import pool_row, pool_write_row


# ---------------------------------------------------------------------------
# contiguous (slot-row) programs
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, max_len: int):
    """Whole-prompt prefill: (params, tokens(B,S), cache) ->
    (logits(B,1,V), cache). Shared positions arange(S) — the wave path and
    the dry-run's prefill cells."""

    def prefill(params, tokens, cache):
        s = tokens.shape[1]
        logits, cache, _ = lm_apply(
            params, cfg, tokens, positions=jnp.arange(s), cache=cache,
            mode="prefill", last_only=True,
        )
        return logits, cache

    return prefill


def make_decode_step(cfg, telemetry: bool = False):
    """(params, tokens(B,1), pos(B,), cache) -> (logits(B,1,V), cache
    [, telem]). Per-row positions; rows with pos<0 are inactive no-ops."""

    def decode(params, tokens, pos, cache):
        out = lm_apply(
            params, cfg, tokens, positions=pos[:, None], cache=cache,
            mode="decode", telemetry=telemetry,
        )
        if telemetry:
            return out[0], out[1], out[3]
        return out[0], out[1]

    return decode


def make_prefill_chunk_step(cfg, telemetry: bool = False):
    """Chunked prefill into one pool slot: (params, pool_cache, logits_buf,
    slot, tokens(1,C), positions(1,C)) -> (pool_cache, logits_buf).

    mode="decode" with S>1 makes attention read prior chunks back out of
    the cache (and the SSM paths continue from their recurrent state), so
    chunks compose exactly; left-pad tokens carry position -1 and touch
    nothing."""

    def prefill_chunk(params, cache, buf, slot, tokens, positions):
        row = pool_row(cache, slot)
        out = lm_apply(
            params, cfg, tokens, positions=positions, cache=row,
            mode="decode", last_only=True, telemetry=telemetry,
        )
        logits, row = out[0], out[1]
        cache = pool_write_row(cache, slot, row)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, logits[:, -1].astype(buf.dtype), slot, axis=0
        )
        if telemetry:
            return cache, buf, out[3]
        return cache, buf

    return prefill_chunk


def make_verify_step(cfg, telemetry: bool = False):
    """Speculative-decoding verify: (params, tokens(B,S), pos(B,S), cache)
    -> (logits(B,S,V), cache). A multi-token decode continuation over the
    contiguous pool (chunked-prefill semantics: this call's KV is written
    first, each lane attends everything causally at or before it), with
    logits at EVERY lane — lane j's logits are the target distribution
    for the token after lane j, which the accept/resample step
    (sampling.spec_accept_tokens) scores the drafts against. Lanes with
    pos < 0 (inactive rows, unused draft lanes) are exact no-ops. One
    fixed (B, k+1) signature: request churn and per-row draft counts
    change values, never shapes."""

    def verify(params, tokens, pos, cache):
        out = lm_apply(
            params, cfg, tokens, positions=pos, cache=cache, mode="decode",
            telemetry=telemetry,
        )
        if telemetry:
            return out[0], out[1], out[3]
        return out[0], out[1]

    return verify


def invalidate_positions_program(cache, positions):
    """Speculative rollback (contiguous): pos -> -1 for a (B, W) batch of
    absolute positions in every attention layer (lanes < 0 drop). Leaves
    the cache equal to never having written the rejected draft lanes."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "attn" in c:
            c["attn"] = invalidate_kv_positions(c["attn"], positions)
        out.append(c)
    return out


# ---------------------------------------------------------------------------
# paged (block-pool) programs
# ---------------------------------------------------------------------------


def _ssm_row_view(cache, slot):
    """Batch-1 view of one slot: attention block pools pass through whole
    (they are row-independent — addressing goes through the table), SSM
    leaves are sliced to the slot's row."""
    view = []
    for layer in cache:
        c = {}
        if "attn" in layer:
            c["attn"] = layer["attn"]
        if "ssm" in layer:
            c["ssm"] = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
                layer["ssm"],
            )
        view.append(c)
    return view


def _ssm_row_merge(cache, new_view, slot):
    """Inverse of `_ssm_row_view`: adopt updated attention pools wholesale,
    scatter the batch-1 SSM rows back into the slot."""
    out = []
    for layer, nl in zip(cache, new_view):
        c = dict(layer)
        if "attn" in c:
            c["attn"] = nl["attn"]
        if "ssm" in c:
            c["ssm"] = jax.tree_util.tree_map(
                lambda a, r: jax.lax.dynamic_update_slice_in_dim(
                    a, r.astype(a.dtype), slot, axis=0
                ),
                layer["ssm"], nl["ssm"],
            )
        out.append(c)
    return out


def make_prefill_chunk_paged(cfg, telemetry: bool = False):
    """Chunked prefill through a block table: (params, cache, logits_buf,
    slot, table(1,nb), tokens(1,C), positions(1,C)) -> (cache, buf
    [, telem]). Attention writes scatter into the slot's table blocks;
    SSM state lives in the slot row as in the contiguous path."""

    def prefill_chunk(params, cache, buf, slot, table, tokens, positions):
        view = _ssm_row_view(cache, slot)
        out = lm_apply(
            params, cfg, tokens, positions=positions, cache=view,
            mode="decode", last_only=True, block_tables=table,
            telemetry=telemetry,
        )
        logits, view = out[0], out[1]
        cache = _ssm_row_merge(cache, view, slot)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, logits[:, -1].astype(buf.dtype), slot, axis=0
        )
        if telemetry:
            return cache, buf, out[3]
        return cache, buf

    return prefill_chunk


def make_decode_step_paged(cfg, use_kernel: bool = False,
                           telemetry: bool = False):
    """(params, tokens(B,1), pos(B,), tables(B,nb), cache) ->
    (logits(B,1,V), cache). Rows with pos<0 are inactive; their (all-null)
    table rows contribute only masked-out keys.

    ``use_kernel`` routes GQA attention through the Pallas
    paged-attention kernel (kernels/paged_attention_kernels.py), which
    streams pool tiles in place — no per-step (B, blocks_per_row *
    block_size, ...) row-view gather in the decode jaxpr (proved by
    ``benchmarks.bench_kernels.check_paged_materialization``). The
    default jnp gather path is the bit-exact oracle."""

    def decode(params, tokens, pos, tables, cache):
        out = lm_apply(
            params, cfg, tokens, positions=pos[:, None], cache=cache,
            mode="decode", block_tables=tables, paged_kernel=use_kernel,
            telemetry=telemetry,
        )
        if telemetry:
            return out[0], out[1], out[3]
        return out[0], out[1]

    return decode


def make_verify_step_paged(cfg, use_kernel: bool = False,
                           telemetry: bool = False):
    """Paged speculative verify: (params, tokens(B,S), pos(B,S),
    tables(B,nb), cache) -> (logits(B,S,V), cache). Same contract as
    `make_verify_step` through the block tables. ``use_kernel`` is
    accepted for signature parity with the decode program, but S > 1
    always takes the jnp gather route (see layers/attention.py — the
    Pallas kernel is single-query)."""

    def verify(params, tokens, pos, tables, cache):
        out = lm_apply(
            params, cfg, tokens, positions=pos, cache=cache,
            mode="decode", block_tables=tables, paged_kernel=use_kernel,
            telemetry=telemetry,
        )
        if telemetry:
            return out[0], out[1], out[3]
        return out[0], out[1]

    return verify


def invalidate_positions_paged_program(cache, positions, tables):
    """Speculative rollback (paged): pos -> -1 through the block tables
    for a (B, W) batch of absolute positions in every attention layer."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "attn" in c:
            c["attn"] = invalidate_paged_positions(
                c["attn"], positions, tables
            )
        out.append(c)
    return out


def clear_blocks_program(cache, blocks):
    """Invalidate a (W,) padded batch of physical blocks across every
    attention layer (pos -> -1) and return the cache. Freed blocks are
    cleared lazily at their next allocation, exactly like contiguous slot
    rows. Jit-safe."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "attn" in c:
            c["attn"] = reset_block_pos(c["attn"], blocks)
        out.append(c)
    return out


def copy_blocks_program(cache, src, dst):
    """Copy physical blocks src[i] -> dst[i] in every attention layer
    (copy-on-write fork). Padded lanes carry out-of-range ids and drop."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "attn" in c:
            c["attn"] = copy_kv_blocks(c["attn"], src, dst)
        out.append(c)
    return out


def clear_ssm_slot_program(cache, slot):
    """Zero one slot's SSM rows (paged acquire — attention needs no clear
    here because block invalidation happens per block at allocation)."""
    out = []
    for layer in cache:
        c = dict(layer)
        if "ssm" in c:
            c["ssm"] = reset_ssm_rows(c["ssm"], slot)
        out.append(c)
    return out
