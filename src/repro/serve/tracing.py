"""Per-request trace timelines + engine tick flight recorder.

Everything here is HOST-SIDE ONLY: span events and tick records are
plain python appended around the jitted calls, never inside them, so
enabling tracing cannot change a single compiled program
(``jit_cache_sizes`` frozen — asserted in tests/test_observability.py)
and cannot change a single served token (bit-identical outputs with
tracing on vs off, greedy and sampled, both backends).

Three surfaces:

* ``Tracer`` — each ``Request`` accumulates typed span events
  ``(t, kind, attrs)`` with monotonic ``perf_counter`` timestamps:
  submitted, admitted(slot, cached), prefill_chunk(i), decode_tick,
  spec_burst(drafted, accepted, committed), preempted/requeued,
  kernel_fallback, retired(reason). ``timeline(req)`` returns the
  structured dict; ``render_timeline(reqs)`` draws a text Gantt
  (examples/serve_async.py --trace); ``validate_timeline(req)`` is the
  consistency contract the chaos harness asserts for every terminal
  request: monotonic timestamps, exactly one submitted/retired pair,
  the retired reason equal to ``finish_reason``, shed requests never
  admitted, and committed-token spans after the last requeue summing to
  ``len(req.out)``.
* ``FlightRecorder`` — bounded ring buffer of per-tick engine records
  (queue/batch occupancy, blocks free/live, tokens emitted, jit-cache
  sizes, per-program host wall time). ``dump(reason, path)`` freezes the
  ring for a post-mortem; ``Watchdog.on_stall`` and the server's
  pump-crash path call it automatically.
* ``ProgramTimer`` — transparent wrapper around one jitted callable
  accumulating host-side call counts and wall time; attribute access
  (``_cache_size`` etc.) passes through to the wrapped function, so the
  zero-recompile accounting sees the same object it always did.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

# -- span kinds --------------------------------------------------------------

SPAN_SUBMITTED = "submitted"
SPAN_ADMITTED = "admitted"
SPAN_PREFILL_CHUNK = "prefill_chunk"
SPAN_DECODE_TICK = "decode_tick"
SPAN_SPEC_BURST = "spec_burst"
SPAN_PREEMPTED = "preempted"
SPAN_REQUEUED = "requeued"
SPAN_KERNEL_FALLBACK = "kernel_fallback"
SPAN_RETIRED = "retired"

SPAN_KINDS = (
    SPAN_SUBMITTED, SPAN_ADMITTED, SPAN_PREFILL_CHUNK, SPAN_DECODE_TICK,
    SPAN_SPEC_BURST, SPAN_PREEMPTED, SPAN_REQUEUED, SPAN_KERNEL_FALLBACK,
    SPAN_RETIRED,
)

# Terminal reasons that imply the request actually ran (was admitted and
# prefetched at least one chunk). Abnormal reasons can land at any stage.
_RAN_TO_COMPLETION = {"eos", "length", "cache_ceiling"}


class Tracer:
    """Appends span events to ``Request.spans`` (created lazily at
    ``start``; requests submitted while tracing is off keep spans=None
    and cost one ``is None`` check per would-be span)."""

    def __init__(self):
        self.started = 0
        self.spans_recorded = 0

    def start(self, req):
        """First sight of a request (engine submit). Idempotent — a
        retry after a shed re-enters submit but keeps one timeline."""
        if req.spans is None:
            req.spans = []
            self.started += 1
            self.span(req, SPAN_SUBMITTED)

    def span(self, req, kind: str, **attrs):
        if req.spans is not None:
            req.spans.append((time.perf_counter(), kind, attrs))
            self.spans_recorded += 1

    def shed(self, req):
        """Terminal span for a request admission control rejected —
        it never reached the engine's submit, so open its timeline
        here."""
        self.start(req)
        self.span(req, SPAN_RETIRED, reason="shed")


def timeline(req) -> dict:
    """Structured view of one request's spans: timestamps relative to
    submission, plus the derived queue/ttft/total durations."""
    spans = req.spans or []
    t0 = spans[0][0] if spans else 0.0
    out = {
        "finish_reason": req.finish_reason,
        "n_spans": len(spans),
        "n_tokens": len(req.out),
        "spans": [
            {"t": t - t0, "kind": kind, **attrs}
            for t, kind, attrs in spans
        ],
    }
    by_kind = {}
    for t, kind, _ in spans:
        by_kind.setdefault(kind, t)
    if SPAN_ADMITTED in by_kind:
        out["queue_s"] = by_kind[SPAN_ADMITTED] - t0
    if req.t_first_token:
        out["ttft_s"] = req.t_first_token - req.t_submit
    if spans:
        out["total_s"] = spans[-1][0] - t0
    return out


def validate_timeline(req) -> None:
    """Assert one terminal request's span sequence is consistent with
    its finish_reason (the chaos harness runs this over every request).
    Raises AssertionError with context on any violation."""
    assert req.done, "validate_timeline on a non-terminal request"
    spans = req.spans
    assert spans, "terminal request carries no spans"
    ts = [t for t, _, _ in spans]
    assert all(b >= a for a, b in zip(ts, ts[1:])), (
        "non-monotonic span timestamps"
    )
    kinds = [k for _, k, _ in spans]
    unknown = [k for k in kinds if k not in SPAN_KINDS]
    assert not unknown, f"unknown span kinds {unknown}"
    assert kinds[0] == SPAN_SUBMITTED, f"first span {kinds[0]!r}"
    assert kinds.count(SPAN_SUBMITTED) == 1, "duplicate submitted span"
    assert kinds[-1] == SPAN_RETIRED, (
        f"terminal request missing retired span (last: {kinds[-1]!r})"
    )
    assert kinds.count(SPAN_RETIRED) == 1, "duplicate retired span"
    reason = spans[-1][2].get("reason")
    assert reason == req.finish_reason, (
        f"retired span reason {reason!r} != finish_reason "
        f"{req.finish_reason!r}"
    )
    assert kinds.count(SPAN_PREEMPTED) == kinds.count(SPAN_REQUEUED), (
        "unpaired preempted/requeued spans"
    )
    if req.finish_reason == "shed":
        assert SPAN_ADMITTED not in kinds, "shed request was admitted"
        return
    if req.finish_reason in _RAN_TO_COMPLETION:
        assert SPAN_ADMITTED in kinds, "completed without admission span"
        assert SPAN_PREFILL_CHUNK in kinds, (
            "completed without any prefill chunk"
        )
    # Token accounting: everything before the last requeue was discarded
    # (req.out reset); after it, one decode_tick span per committed
    # token plus spec bursts' committed counts must equal len(req.out).
    start = 0
    for i, k in enumerate(kinds):
        if k == SPAN_REQUEUED:
            start = i + 1
    committed = 0
    for _, kind, attrs in spans[start:]:
        if kind == SPAN_DECODE_TICK:
            committed += 1
        elif kind == SPAN_SPEC_BURST:
            committed += int(attrs.get("committed", 0))
    assert committed == len(req.out), (
        f"span token count {committed} != emitted tokens {len(req.out)} "
        f"(finish_reason={req.finish_reason!r})"
    )


def render_timeline(reqs: Sequence, width: int = 64) -> str:
    """Text Gantt over a set of traced requests: one row per request,
    Q = queued, P = prefilling, D = decoding, with markers x (preempted),
    ! (kernel fallback) and the finish_reason + token count per row."""
    traced = [r for r in reqs if r.spans]
    if not traced:
        return "(no traced requests)"
    t0 = min(r.spans[0][0] for r in traced)
    t1 = max(r.spans[-1][0] for r in traced)
    span_s = max(t1 - t0, 1e-9)

    def col(t: float) -> int:
        return min(width - 1, int((t - t0) / span_s * width))

    lines = [
        f"timeline: {span_s * 1e3:.1f} ms total, {len(traced)} requests "
        f"(Q queued, P prefill, D decode, x preempt, ! kernel-fallback)"
    ]
    for i, r in enumerate(traced):
        row = [" "] * width
        # phase boundaries: submitted -> admitted -> first decode -> end
        marks: Dict[str, List[float]] = {}
        for t, kind, _ in r.spans:
            marks.setdefault(kind, []).append(t)
        t_sub = marks[SPAN_SUBMITTED][0]
        t_end = r.spans[-1][0]
        admits = marks.get(SPAN_ADMITTED, [])
        decodes = (marks.get(SPAN_DECODE_TICK, [])
                   + marks.get(SPAN_SPEC_BURST, []))
        t_adm = min(admits) if admits else t_end
        t_dec = min(decodes) if decodes else t_end
        for c in range(col(t_sub), col(t_end) + 1):
            if c < col(t_adm):
                row[c] = "Q"
            elif c < col(t_dec):
                row[c] = "P"
            else:
                row[c] = "D"
        for t in marks.get(SPAN_PREEMPTED, []):
            row[col(t)] = "x"
        for t in marks.get(SPAN_KERNEL_FALLBACK, []):
            row[col(t)] = "!"
        reason = r.finish_reason or "?"
        lines.append(
            f"req {i:>3} |{''.join(row)}| {reason:<13} "
            f"{len(r.out):>3} tok"
        )
    return "\n".join(lines)


# -- flight recorder ---------------------------------------------------------


class FlightRecorder:
    """Bounded ring of per-tick engine records for post-mortems.

    The engine appends one dict per tick (see ServeEngine.step for the
    schema — docs/observability.md documents it); ``dump`` freezes the
    current ring with a reason tag, optionally writing JSON to a path.
    ``ticks`` counts every record ever seen (the ring holds the last
    ``capacity``)."""

    def __init__(self, capacity: int = 256):
        assert capacity >= 1
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.ticks = 0
        self.dumps = 0
        self.last_dump: Optional[dict] = None
        self.last_dump_path: Optional[str] = None

    def record(self, rec: dict):
        self.ticks += 1
        self._ring.append(rec)

    def records(self) -> List[dict]:
        return list(self._ring)

    def dump(self, reason: str, path: Optional[str] = None) -> dict:
        out = {
            "reason": reason,
            "ticks_seen": self.ticks,
            "capacity": self.capacity,
            "records": self.records(),
        }
        self.dumps += 1
        self.last_dump = out
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f, indent=1, default=str)
            self.last_dump_path = path
        return out

    def render(self, last: int = 12, records=None) -> str:
        """Compact text table of the most recent records — of the live
        ring, or of an explicit record list (e.g. a frozen
        ``dump["records"]``)."""
        recs = (self.records() if records is None else list(records))[-last:]
        if not recs:
            return "(flight recorder empty)"
        lines = ["tick  live queued emit adm  programs"]
        for r in recs:
            progs = ",".join(
                f"{k}:{v['calls']}" for k, v in
                sorted(r.get("programs", {}).items()) if v["calls"]
            ) or "-"
            lines.append(
                f"{r.get('tick', 0):>5} {r.get('live', 0):>4}"
                f" {r.get('queued', 0):>6} {r.get('emitted', 0):>4}"
                f" {r.get('admitted', 0):>3}  {progs}"
            )
        return "\n".join(lines)


# -- per-program host timing -------------------------------------------------


class ProgramTimer:
    """Wrap one jitted callable with host-side wall-time accounting.

    ``calls``/``total_s`` accumulate for the wrapper's lifetime;
    ``take_tick()`` drains the per-tick delta the flight recorder
    stores. Unknown attributes (``_cache_size``, ...) pass through to
    the wrapped function, so jit-cache introspection is unchanged."""

    def __init__(self, name: str, fn):
        self.name = name
        self.fn = fn
        self.calls = 0
        self.total_s = 0.0
        self._tick_calls = 0
        self._tick_s = 0.0

    def __call__(self, *args, **kwargs):
        t0 = time.perf_counter()
        out = self.fn(*args, **kwargs)
        dt = time.perf_counter() - t0
        self.calls += 1
        self.total_s += dt
        self._tick_calls += 1
        self._tick_s += dt
        return out

    def take_tick(self) -> dict:
        out = {"calls": self._tick_calls, "s": round(self._tick_s, 6)}
        self._tick_calls = 0
        self._tick_s = 0.0
        return out

    def reset(self):
        """Zero the lifetime accumulators — benches call this after the
        compile-warmup request so ``program_efficiency()`` attributes
        only steady-state calls, not the first trace-and-compile."""
        self.calls = 0
        self.total_s = 0.0
        self._tick_calls = 0
        self._tick_s = 0.0

    def __getattr__(self, name):
        if name == "fn":  # not yet set (mid-__init__): avoid recursion
            raise AttributeError(name)
        return getattr(self.fn, name)
