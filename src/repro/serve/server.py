"""Fault-tolerant asyncio serving front end over ``ServeEngine``.

``AsyncServer`` wraps a (synchronous, single-threaded) ``ServeEngine``
in an asyncio event loop without changing a single token it produces:
requests enter the engine through the same FIFO ``submit`` path, the
engine steps inside one pump task (jax stays on one thread), and tokens
stream out through per-request ``asyncio.Queue``s fed by the engine's
``on_token`` callback. On a no-fault trace the server's outputs are
token-for-token identical to driving the engine directly — asserted for
greedy AND sampled requests in tests/test_server.py.

What the wrapper adds is the failure policy the bare engine doesn't
have:

* **Admission control + load shedding.** Before a request reaches the
  engine, two budgets gate it: the scheduler's bounded queue
  (``QueueFull`` -> shed reason "queue_full") and estimated token
  demand — the sum of ``len(prompt) + max_new_tokens`` over every
  queued and live request may not exceed ``max_demand_factor`` × the
  backend's ``token_capacity()`` (shed reason "memory"). A shed is an
  explicit, reasoned reject (``ShedError``), never a silent drop.
* **Retry with backoff.** A shed submission retries up to
  ``max_retries`` times with exponential backoff before the request is
  finalized with ``finish_reason="shed"``; retries respect the
  request's deadline (no point backing off past it).
* **Deadlines.** Per-request TTFT / total deadlines ride on the
  Request fields the engine's tick loop already enforces
  (finish_reason="deadline"); the server just fills defaults and
  surfaces the misses as metrics.
* **Cancellation.** Closing a ``stream()``/``generate()`` consumer (or
  calling ``cancel(req)``) retires the row and frees its slot, blocks,
  and pending speculative state within one engine tick — the engine's
  synchronous ``cancel`` does the freeing; the server just routes it.
* **Watchdog.** The pump feeds a stuck-step ``Watchdog``
  (serve/metrics.py): pending work with no progress for ``stall_s``
  raises the ``watchdog_stalls`` counter.

The pump never lets an engine exception kill streams silently: a
crashed pump finalizes every open request with finish_reason="error"
and wakes its consumers.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass
from typing import AsyncIterator, Dict, List, Optional

from .metrics import ServeMetrics, Watchdog, collect_engine_metrics
from .sampling import GREEDY, SamplingParams
from .scheduler import QueueFull, Request

_DONE = object()  # per-request stream sentinel


class ShedError(RuntimeError):
    """Admission control rejected a request. ``reason`` is "queue_full"
    (bounded scheduler queue at capacity) or "memory" (estimated token
    demand over budget)."""

    def __init__(self, reason: str):
        super().__init__(f"request shed: {reason}")
        self.reason = reason


@dataclass
class ServerConfig:
    """Front-end policy knobs (the engine's own config is orthogonal).

    ``max_queue`` is applied to the engine's scheduler if it doesn't
    already bound its queue. ``max_demand_factor`` scales the memory
    budget: outstanding token demand (queued + live) may reach that
    multiple of ``backend.token_capacity()`` — above it, new work is
    shed with reason "memory" rather than queued into unbounded wait.
    """

    max_queue: int = 32
    max_demand_factor: float = 4.0
    max_retries: int = 2
    retry_backoff_s: float = 0.05
    # Pump sleep when the engine has nothing to do (keeps the loop
    # responsive to new submissions without spinning).
    idle_sleep_s: float = 0.002
    watchdog_stall_s: float = 30.0
    # Defaults applied to requests that don't set their own deadlines
    # (None = no deadline).
    default_ttft_deadline_s: Optional[float] = None
    default_deadline_s: Optional[float] = None


class AsyncServer:
    """Asyncio front end: submit/stream/cancel over one ``ServeEngine``.

    Use as an async context manager (starts/stops the pump task)::

        async with AsyncServer(engine) as srv:
            async for tok in srv.generate([1, 2, 3], max_new_tokens=8):
                ...

    or ``start()`` / ``stop()`` explicitly. All methods must be called
    from the event loop thread — the engine itself is never shared
    across threads.
    """

    def __init__(self, engine, config: Optional[ServerConfig] = None,
                 metrics: Optional[ServeMetrics] = None):
        self.eng = engine
        self.config = config or ServerConfig()
        self.metrics = metrics or ServeMetrics()
        self.watchdog = Watchdog(
            self.config.watchdog_stall_s,
            on_stall=lambda s: self.metrics.inc("watchdog_stalls"),
        )
        if self.eng.sched.max_queue is None:
            self.eng.sched.max_queue = self.config.max_queue
        # id(req) -> stream queue; Requests are mutable dataclasses
        # (unhashable), and identity is exactly the lifetime we track.
        self._streams: Dict[int, asyncio.Queue] = {}
        self._open: Dict[int, Request] = {}
        self._pump_task: Optional[asyncio.Task] = None
        self._running = False
        self._wake = asyncio.Event()  # submission -> pump wakes instantly

    # -- lifecycle ---------------------------------------------------------

    async def start(self):
        assert self._pump_task is None, "server already started"
        self._running = True
        self._pump_task = asyncio.create_task(self._pump())

    async def stop(self):
        """Stop the pump; any still-open request is cancelled (its
        resources free through the engine's normal cancel path)."""
        self._running = False
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None
        for req in list(self._open.values()):
            self.eng.cancel(req)
            self.metrics.inc("cancellations_shutdown")
        self._finalize_done()

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.stop()

    # -- admission ---------------------------------------------------------

    def _outstanding_tokens(self) -> int:
        sched = self.eng.sched
        return (
            sum(len(r.prompt) + r.max_new_tokens for r in sched.queue)
            + sum(len(e.req.prompt) + e.req.max_new_tokens
                  for e in sched.live.values())
        )

    def _try_submit(self, req: Request):
        demand = len(req.prompt) + req.max_new_tokens
        budget = (self.config.max_demand_factor
                  * self.eng.backend.token_capacity())
        if self._outstanding_tokens() + demand > budget:
            raise ShedError("memory")
        try:
            self.eng.submit(req)
        except QueueFull:
            raise ShedError("queue_full") from None

    def _register(self, req: Request) -> asyncio.Queue:
        q: asyncio.Queue = asyncio.Queue()
        self._streams[id(req)] = q
        self._open[id(req)] = req

        def on_token(r: Request, tok: int):
            q.put_nowait(tok)

        req.on_token = on_token
        return q

    def _finalize(self, req: Request):
        """Close a request's stream (idempotent)."""
        q = self._streams.pop(id(req), None)
        self._open.pop(id(req), None)
        if q is not None:
            q.put_nowait(_DONE)

    async def submit(self, prompt: List[int], max_new_tokens: int = 16,
                     sampling: SamplingParams = GREEDY,
                     ttft_deadline_s: Optional[float] = None,
                     deadline_s: Optional[float] = None) -> Request:
        """Admit a request (retrying sheds with backoff) and return it.
        Raises ``ShedError`` — with the request finalized as
        finish_reason="shed" — if every attempt was rejected."""
        cfg = self.config
        req = Request(
            prompt=list(prompt), max_new_tokens=max_new_tokens,
            sampling=sampling,
            ttft_deadline_s=(ttft_deadline_s if ttft_deadline_s is not None
                             else cfg.default_ttft_deadline_s),
            deadline_s=(deadline_s if deadline_s is not None
                        else cfg.default_deadline_s),
        )
        self._register(req)
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                self._try_submit(req)
                self.metrics.inc("submitted")
                self._wake.set()
                return req
            except ShedError as e:
                if attempt >= cfg.max_retries or self._past_deadline(req, t0):
                    req.done = True
                    req.finish_reason = "shed"
                    req.t_done = time.perf_counter()
                    self.metrics.inc("sheds")
                    self.metrics.inc(f"shed_{e.reason}")
                    self._finalize(req)
                    raise
                self.metrics.inc("shed_retries")
                await asyncio.sleep(cfg.retry_backoff_s * (2 ** attempt))
                attempt += 1

    @staticmethod
    def _past_deadline(req: Request, t0: float) -> bool:
        if req.deadline_s is None:
            return False
        return time.perf_counter() - t0 >= req.deadline_s

    # -- streaming ---------------------------------------------------------

    async def stream(self, req: Request) -> AsyncIterator[int]:
        """Yield `req`'s tokens as the engine emits them; ends when the
        request reaches ANY terminal state. Abandoning the iterator
        (break / task cancellation) cancels the request, freeing its
        row within one engine tick."""
        q = self._streams.get(id(req))
        if q is None:  # already finalized — replay nothing
            return
        try:
            while True:
                item = await q.get()
                if item is _DONE:
                    return
                yield item
        finally:
            if not req.done:
                self.cancel(req)

    async def generate(self, prompt: List[int], max_new_tokens: int = 16,
                       sampling: SamplingParams = GREEDY,
                       ttft_deadline_s: Optional[float] = None,
                       deadline_s: Optional[float] = None
                       ) -> AsyncIterator[int]:
        """submit + stream in one call."""
        req = await self.submit(
            prompt, max_new_tokens=max_new_tokens, sampling=sampling,
            ttft_deadline_s=ttft_deadline_s, deadline_s=deadline_s,
        )
        async for tok in self.stream(req):
            yield tok

    async def complete(self, prompt: List[int], max_new_tokens: int = 16,
                       sampling: SamplingParams = GREEDY,
                       ttft_deadline_s: Optional[float] = None,
                       deadline_s: Optional[float] = None) -> Request:
        """Non-streaming convenience: run to a terminal state, return
        the finished Request (`.out`, `.finish_reason`)."""
        req = await self.submit(
            prompt, max_new_tokens=max_new_tokens, sampling=sampling,
            ttft_deadline_s=ttft_deadline_s, deadline_s=deadline_s,
        )
        async for _ in self.stream(req):
            pass
        return req

    def cancel(self, req: Request) -> bool:
        """Client cancellation: frees the request's slot/blocks/pending
        speculative state within one engine tick (immediately if live).
        Safe to call at any time; False if it already finished."""
        hit = self.eng.cancel(req)
        if hit:
            self.metrics.inc("client_cancellations")
        self._finalize(req)
        return hit

    # -- pump --------------------------------------------------------------

    def _finalize_done(self) -> int:
        """Close streams of requests that reached a terminal state and
        record their latency metrics. Returns how many closed."""
        done = [r for r in self._open.values() if r.done]
        for req in done:
            reason = req.finish_reason or "unknown"
            self.metrics.inc(f"finish_{reason}")
            if reason in ("eos", "length", "cache_ceiling"):
                self.metrics.inc("completed")
            if req.t_admitted:
                self.metrics.observe(
                    "queue_time_s", req.t_admitted - req.t_submit)
            if req.t_first_token:
                self.metrics.observe(
                    "ttft_s", req.t_first_token - req.t_submit)
            if req.t_done:
                self.metrics.observe(
                    "latency_s", req.t_done - req.t_submit)
            self._finalize(req)
        return len(done)

    async def _pump(self):
        """The single engine-driving task: step while work is pending,
        close finished streams, feed the watchdog, sleep when idle."""
        try:
            while self._running:
                if self.eng.sched.pending():
                    emitted = self.eng.step()
                    closed = self._finalize_done()
                    self.watchdog.beat(emitted > 0 or closed > 0,
                                       self.eng.sched.pending())
                    # Yield so submit()/cancel() callers interleave.
                    await asyncio.sleep(0)
                else:
                    self._finalize_done()
                    self.watchdog.beat(False, False)
                    self._wake.clear()
                    try:
                        await asyncio.wait_for(
                            self._wake.wait(), self.config.idle_sleep_s)
                    except asyncio.TimeoutError:
                        pass
        except Exception:
            # Engine crash: never strand consumers — every open request
            # terminates with finish_reason="error" and its stream ends.
            for req in list(self._open.values()):
                if not req.done:
                    req.done = True
                    req.finish_reason = "error"
                    req.t_done = time.perf_counter()
                self.metrics.inc("finish_error")
                self._finalize(req)
            raise

    # -- introspection -----------------------------------------------------

    def snapshot(self) -> dict:
        """Server metrics + engine robustness counters + watchdog, as
        one flat dict (the bench exports this into BENCH_serve.json)."""
        collect_engine_metrics(self.eng, self.metrics)
        self.metrics.counters["watchdog_stalls"] = self.watchdog.stalls
        return self.metrics.snapshot()
