"""Fault-tolerant asyncio serving front end over ``ServeEngine``.

``AsyncServer`` wraps a (synchronous, single-threaded) ``ServeEngine``
in an asyncio event loop without changing a single token it produces:
requests enter the engine through the same FIFO ``submit`` path, the
engine steps inside one pump task (jax stays on one thread), and tokens
stream out through per-request ``asyncio.Queue``s fed by the engine's
``on_token`` callback. On a no-fault trace the server's outputs are
token-for-token identical to driving the engine directly — asserted for
greedy AND sampled requests in tests/test_server.py.

What the wrapper adds is the failure policy the bare engine doesn't
have:

* **Admission control + load shedding.** Before a request reaches the
  engine, two budgets gate it: the scheduler's bounded queue
  (``QueueFull`` -> shed reason "queue_full") and estimated token
  demand — the sum of ``len(prompt) + max_new_tokens`` over every
  queued and live request may not exceed ``max_demand_factor`` × the
  backend's ``token_capacity()`` (shed reason "memory"). A shed is an
  explicit, reasoned reject (``ShedError``), never a silent drop.
* **Retry with backoff.** A shed submission retries up to
  ``max_retries`` times with exponential backoff before the request is
  finalized with ``finish_reason="shed"``; retries respect the
  request's deadline (no point backing off past it).
* **Deadlines.** Per-request TTFT / total deadlines ride on the
  Request fields the engine's tick loop already enforces
  (finish_reason="deadline"); the server just fills defaults and
  surfaces the misses as metrics.
* **Cancellation.** Closing a ``stream()``/``generate()`` consumer (or
  calling ``cancel(req)``) retires the row and frees its slot, blocks,
  and pending speculative state within one engine tick — the engine's
  synchronous ``cancel`` does the freeing; the server just routes it.
* **Watchdog.** The pump feeds a stuck-step ``Watchdog``
  (serve/metrics.py): pending work with no progress for ``stall_s``
  raises the ``watchdog_stalls`` counter, records the stall duration as
  the ``watchdog_stall_s`` series, and — when the engine runs a flight
  recorder — dumps the per-tick ring for a post-mortem (to
  ``dump_dir`` if set, else in memory as ``recorder.last_dump``).
* **Observability endpoints.** With ``metrics_port`` set (0 = pick an
  ephemeral port) the server answers HTTP GETs on ``/metrics``
  (Prometheus text format, serve/exporter.py — counters, latency
  histograms, and the frozen ``engine_info`` gauge) and ``/healthz``
  (JSON liveness: pump state, queue depth, stall count; 503 once the
  pump has crashed).

The pump never lets an engine exception kill streams silently: a
crashed pump finalizes every open request with finish_reason="error",
wakes its consumers, and dumps the flight recorder (reason
"pump_crash") when one is attached.
"""
from __future__ import annotations

import asyncio
import json
import os
import time
from dataclasses import dataclass
from typing import AsyncIterator, Dict, List, Optional

from .exporter import CONTENT_TYPE, render_prometheus
from .metrics import ServeMetrics, Watchdog, collect_engine_metrics
from .sampling import GREEDY, SamplingParams
from .scheduler import QueueFull, Request

_DONE = object()  # per-request stream sentinel


class ShedError(RuntimeError):
    """Admission control rejected a request. ``reason`` is "queue_full"
    (bounded scheduler queue at capacity) or "memory" (estimated token
    demand over budget)."""

    def __init__(self, reason: str):
        super().__init__(f"request shed: {reason}")
        self.reason = reason


@dataclass
class ServerConfig:
    """Front-end policy knobs (the engine's own config is orthogonal).

    ``max_queue`` is applied to the engine's scheduler if it doesn't
    already bound its queue. ``max_demand_factor`` scales the memory
    budget: outstanding token demand (queued + live) may reach that
    multiple of ``backend.token_capacity()`` — above it, new work is
    shed with reason "memory" rather than queued into unbounded wait.
    """

    max_queue: int = 32
    max_demand_factor: float = 4.0
    max_retries: int = 2
    retry_backoff_s: float = 0.05
    # Pump sleep when the engine has nothing to do (keeps the loop
    # responsive to new submissions without spinning).
    idle_sleep_s: float = 0.002
    watchdog_stall_s: float = 30.0
    # Defaults applied to requests that don't set their own deadlines
    # (None = no deadline).
    default_ttft_deadline_s: Optional[float] = None
    default_deadline_s: Optional[float] = None
    # Observability: None = no HTTP endpoints; 0 = bind an ephemeral
    # port (read it back from ``srv.metrics_addr``).
    metrics_port: Optional[int] = None
    metrics_host: str = "127.0.0.1"
    # Where watchdog/pump-crash flight-recorder dumps are written as
    # JSON (one file per dump); None keeps them in memory only.
    dump_dir: Optional[str] = None


class AsyncServer:
    """Asyncio front end: submit/stream/cancel over one ``ServeEngine``.

    Use as an async context manager (starts/stops the pump task)::

        async with AsyncServer(engine) as srv:
            async for tok in srv.generate([1, 2, 3], max_new_tokens=8):
                ...

    or ``start()`` / ``stop()`` explicitly. All methods must be called
    from the event loop thread — the engine itself is never shared
    across threads.
    """

    def __init__(self, engine, config: Optional[ServerConfig] = None,
                 metrics: Optional[ServeMetrics] = None):
        self.eng = engine
        self.config = config or ServerConfig()
        self.metrics = metrics or ServeMetrics()
        self.watchdog = Watchdog(
            self.config.watchdog_stall_s, on_stall=self._on_stall,
        )
        if self.eng.sched.max_queue is None:
            self.eng.sched.max_queue = self.config.max_queue
        # id(req) -> stream queue; Requests are mutable dataclasses
        # (unhashable), and identity is exactly the lifetime we track.
        self._streams: Dict[int, asyncio.Queue] = {}
        self._open: Dict[int, Request] = {}
        self._pump_task: Optional[asyncio.Task] = None
        self._running = False
        self._crashed = False
        self._wake = asyncio.Event()  # submission -> pump wakes instantly
        self._http_server: Optional[asyncio.AbstractServer] = None
        self.metrics_addr: Optional[tuple] = None  # (host, port) once bound

    # -- observability hooks -----------------------------------------------

    def _on_stall(self, stalled_for: float):
        """Watchdog callback: count + record the stall duration, and
        freeze the engine's flight recorder for the post-mortem."""
        self.metrics.inc("watchdog_stalls")
        self.metrics.observe("watchdog_stall_s", stalled_for)
        self._dump_recorder("watchdog_stall")

    def _dump_recorder(self, reason: str) -> Optional[dict]:
        rec = getattr(self.eng, "recorder", None)
        if rec is None:
            return None
        path = None
        if self.config.dump_dir is not None:
            path = os.path.join(
                self.config.dump_dir,
                f"flight_{reason}_{rec.dumps}.json",
            )
        return rec.dump(reason, path=path)

    # -- lifecycle ---------------------------------------------------------

    async def start(self):
        assert self._pump_task is None, "server already started"
        self._running = True
        self._pump_task = asyncio.create_task(self._pump())
        if self.config.metrics_port is not None:
            await self.start_metrics_server()

    async def start_metrics_server(self, host: Optional[str] = None,
                                   port: Optional[int] = None) -> int:
        """Bind the /metrics + /healthz HTTP listener; returns the bound
        port (useful with port 0). Idempotent per server instance."""
        assert self._http_server is None, "metrics server already bound"
        host = host if host is not None else self.config.metrics_host
        port = port if port is not None else self.config.metrics_port or 0
        self._http_server = await asyncio.start_server(
            self._handle_http, host, port
        )
        bound = self._http_server.sockets[0].getsockname()[1]
        self.metrics_addr = (host, bound)
        return bound

    async def stop(self):
        """Stop the pump; any still-open request is cancelled (its
        resources free through the engine's normal cancel path)."""
        self._running = False
        self._wake.set()
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None
        for req in list(self._open.values()):
            self.eng.cancel(req)
            self.metrics.inc("cancellations_shutdown")
        self._finalize_done()
        if self._http_server is not None:
            self._http_server.close()
            await self._http_server.wait_closed()
            self._http_server = None

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.stop()

    # -- admission ---------------------------------------------------------

    def _outstanding_tokens(self) -> int:
        sched = self.eng.sched
        return (
            sum(len(r.prompt) + r.max_new_tokens for r in sched.queue)
            + sum(len(e.req.prompt) + e.req.max_new_tokens
                  for e in sched.live.values())
        )

    def _try_submit(self, req: Request):
        demand = len(req.prompt) + req.max_new_tokens
        budget = (self.config.max_demand_factor
                  * self.eng.backend.token_capacity())
        if self._outstanding_tokens() + demand > budget:
            raise ShedError("memory")
        try:
            self.eng.submit(req)
        except QueueFull:
            raise ShedError("queue_full") from None

    def _register(self, req: Request) -> asyncio.Queue:
        q: asyncio.Queue = asyncio.Queue()
        self._streams[id(req)] = q
        self._open[id(req)] = req

        def on_token(r: Request, tok: int):
            q.put_nowait(tok)

        req.on_token = on_token
        return q

    def _finalize(self, req: Request):
        """Close a request's stream (idempotent)."""
        q = self._streams.pop(id(req), None)
        self._open.pop(id(req), None)
        if q is not None:
            q.put_nowait(_DONE)

    async def submit(self, prompt: List[int], max_new_tokens: int = 16,
                     sampling: SamplingParams = GREEDY,
                     ttft_deadline_s: Optional[float] = None,
                     deadline_s: Optional[float] = None) -> Request:
        """Admit a request (retrying sheds with backoff) and return it.
        Raises ``ShedError`` — with the request finalized as
        finish_reason="shed" — if every attempt was rejected."""
        cfg = self.config
        req = Request(
            prompt=list(prompt), max_new_tokens=max_new_tokens,
            sampling=sampling,
            ttft_deadline_s=(ttft_deadline_s if ttft_deadline_s is not None
                             else cfg.default_ttft_deadline_s),
            deadline_s=(deadline_s if deadline_s is not None
                        else cfg.default_deadline_s),
        )
        self._register(req)
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                self._try_submit(req)
                self.metrics.inc("submitted")
                self._wake.set()
                return req
            except ShedError as e:
                if attempt >= cfg.max_retries or self._past_deadline(req, t0):
                    req.done = True
                    req.finish_reason = "shed"
                    req.t_done = time.perf_counter()
                    tracer = getattr(self.eng, "tracer", None)
                    if tracer is not None:  # shed never reached submit;
                        tracer.shed(req)    # open+close its timeline here
                    self.metrics.inc("sheds")
                    self.metrics.inc(f"shed_{e.reason}")
                    self._finalize(req)
                    raise
                self.metrics.inc("shed_retries")
                await asyncio.sleep(cfg.retry_backoff_s * (2 ** attempt))
                attempt += 1

    @staticmethod
    def _past_deadline(req: Request, t0: float) -> bool:
        if req.deadline_s is None:
            return False
        return time.perf_counter() - t0 >= req.deadline_s

    # -- streaming ---------------------------------------------------------

    async def stream(self, req: Request) -> AsyncIterator[int]:
        """Yield `req`'s tokens as the engine emits them; ends when the
        request reaches ANY terminal state. Abandoning the iterator
        (break / task cancellation) cancels the request, freeing its
        row within one engine tick."""
        q = self._streams.get(id(req))
        if q is None:  # already finalized — replay nothing
            return
        try:
            while True:
                item = await q.get()
                if item is _DONE:
                    return
                yield item
        finally:
            if not req.done:
                self.cancel(req)

    async def generate(self, prompt: List[int], max_new_tokens: int = 16,
                       sampling: SamplingParams = GREEDY,
                       ttft_deadline_s: Optional[float] = None,
                       deadline_s: Optional[float] = None
                       ) -> AsyncIterator[int]:
        """submit + stream in one call."""
        req = await self.submit(
            prompt, max_new_tokens=max_new_tokens, sampling=sampling,
            ttft_deadline_s=ttft_deadline_s, deadline_s=deadline_s,
        )
        async for tok in self.stream(req):
            yield tok

    async def complete(self, prompt: List[int], max_new_tokens: int = 16,
                       sampling: SamplingParams = GREEDY,
                       ttft_deadline_s: Optional[float] = None,
                       deadline_s: Optional[float] = None) -> Request:
        """Non-streaming convenience: run to a terminal state, return
        the finished Request (`.out`, `.finish_reason`)."""
        req = await self.submit(
            prompt, max_new_tokens=max_new_tokens, sampling=sampling,
            ttft_deadline_s=ttft_deadline_s, deadline_s=deadline_s,
        )
        async for _ in self.stream(req):
            pass
        return req

    def cancel(self, req: Request) -> bool:
        """Client cancellation: frees the request's slot/blocks/pending
        speculative state within one engine tick (immediately if live).
        Safe to call at any time; False if it already finished."""
        hit = self.eng.cancel(req)
        if hit:
            self.metrics.inc("client_cancellations")
        self._finalize(req)
        return hit

    # -- pump --------------------------------------------------------------

    def _finalize_done(self) -> int:
        """Close streams of requests that reached a terminal state and
        record their latency metrics. Returns how many closed."""
        done = [r for r in self._open.values() if r.done]
        for req in done:
            reason = req.finish_reason or "unknown"
            self.metrics.inc(f"finish_{reason}")
            if reason in ("eos", "length", "cache_ceiling"):
                self.metrics.inc("completed")
            if req.t_admitted:
                self.metrics.observe(
                    "queue_time_s", req.t_admitted - req.t_submit)
            if req.t_first_token:
                self.metrics.observe(
                    "ttft_s", req.t_first_token - req.t_submit)
            if req.t_done:
                self.metrics.observe(
                    "latency_s", req.t_done - req.t_submit)
            self._finalize(req)
        return len(done)

    async def _pump(self):
        """The single engine-driving task: step while work is pending,
        close finished streams, feed the watchdog, sleep when idle."""
        try:
            while self._running:
                if self.eng.sched.pending():
                    emitted = self.eng.step()
                    closed = self._finalize_done()
                    self.watchdog.beat(emitted > 0 or closed > 0,
                                       self.eng.sched.pending())
                    # Yield so submit()/cancel() callers interleave.
                    await asyncio.sleep(0)
                else:
                    self._finalize_done()
                    self.watchdog.beat(False, False)
                    self._wake.clear()
                    try:
                        await asyncio.wait_for(
                            self._wake.wait(), self.config.idle_sleep_s)
                    except asyncio.TimeoutError:
                        pass
        except Exception:
            # Engine crash: never strand consumers — every open request
            # terminates with finish_reason="error" and its stream ends.
            # The flight recorder (if any) freezes the last ticks for
            # the post-mortem.
            self._crashed = True
            self._dump_recorder("pump_crash")
            for req in list(self._open.values()):
                if not req.done:
                    req.done = True
                    req.finish_reason = "error"
                    req.t_done = time.perf_counter()
                self.metrics.inc("finish_error")
                self._finalize(req)
            raise

    # -- introspection -----------------------------------------------------

    def _collect_telemetry_gauges(self):
        """Pull the engine's model-interior telemetry (routing health +
        numerics, serve/telemetry.py) and roofline-vs-measured program
        efficiency into labeled gauges. No-ops unless the engine was
        built with telemetry=True."""
        agg = getattr(self.eng, "telemetry", None)
        if agg is not None:
            self.metrics.merge_gauges(agg.gauges())
        eff = getattr(self.eng, "program_efficiency", None)
        if eff is not None:
            for program, ratio in (eff() or {}).items():
                self.metrics.set_gauge(
                    "program_efficiency", ratio, program=program)

    def snapshot(self) -> dict:
        """Server metrics + engine robustness counters + watchdog, as
        one flat dict (the bench exports this into BENCH_serve.json)."""
        collect_engine_metrics(self.eng, self.metrics)
        self.metrics.counters["watchdog_stalls"] = self.watchdog.stalls
        self._collect_telemetry_gauges()
        return self.metrics.snapshot()

    def metrics_text(self) -> str:
        """The /metrics body: a fresh Prometheus text exposition of the
        full metrics surface + the frozen engine-config info gauge."""
        collect_engine_metrics(self.eng, self.metrics)
        self.metrics.counters["watchdog_stalls"] = self.watchdog.stalls
        self._collect_telemetry_gauges()
        info = None
        if hasattr(self.eng, "config_info"):
            info = self.eng.config_info()
        return render_prometheus(self.metrics, info=info)

    def health(self) -> dict:
        """The /healthz body. status "ok" while the pump is alive;
        "crashed" (HTTP 503) once it died on an engine exception."""
        pump_alive = (self._pump_task is not None
                      and not self._pump_task.done())
        status = "crashed" if self._crashed else (
            "ok" if pump_alive or not self._running else "stopped"
        )
        return {
            "status": status,
            "pump_alive": pump_alive,
            "queued": len(self.eng.sched.queue),
            "live": len(self.eng.sched.live),
            "open_streams": len(self._open),
            "watchdog_stalls": self.watchdog.stalls,
        }

    # -- HTTP endpoints ----------------------------------------------------

    async def _handle_http(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter):
        """Minimal HTTP/1.1 responder for pull-based scraping — GET
        /metrics and /healthz only, one request per connection (the
        scrape pattern; no keep-alive, no external deps)."""
        try:
            request_line = await reader.readline()
            parts = request_line.decode("latin-1", "replace").split()
            path = parts[1] if len(parts) >= 2 else "/"
            while True:  # drain headers
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
            route = path.split("?", 1)[0]
            if route == "/metrics":
                status, ctype = 200, CONTENT_TYPE
                body = self.metrics_text()
            elif route == "/healthz":
                h = self.health()
                status = 200 if h["status"] == "ok" else 503
                ctype = "application/json"
                body = json.dumps(h) + "\n"
            else:
                status, ctype = 404, "text/plain; charset=utf-8"
                body = "not found\n"
            data = body.encode("utf-8")
            phrase = {200: "OK", 404: "Not Found",
                      503: "Service Unavailable"}[status]
            writer.write(
                (f"HTTP/1.1 {status} {phrase}\r\n"
                 f"Content-Type: {ctype}\r\n"
                 f"Content-Length: {len(data)}\r\n"
                 "Connection: close\r\n\r\n").encode("latin-1") + data
            )
            await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass
