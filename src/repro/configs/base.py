"""Config system: one dataclass family covering every assigned architecture.

All configs are frozen dataclasses so they can be hashed into jit static
arguments and compared structurally. ``repro.configs.get_config(name)``
returns the full-size published config; ``reduced(cfg)`` returns a tiny
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Self-attention variants: GQA (optionally sliding-window) and MLA."""

    kind: str = "gqa"  # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    qkv_bias: bool = False  # Qwen2 uses bias on QKV projections
    rope_theta: float = 10_000.0
    # Sliding-window attention (gemma3-style): window size for local layers,
    # and every `global_every`-th layer is global (full) attention.
    sliding_window: Optional[int] = None
    global_every: int = 0  # 0 => all layers share `sliding_window` (or full)
    # MLA (DeepSeek-V2) parameters.
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    def is_global_layer(self, layer_idx: int) -> bool:
        if self.sliding_window is None:
            return True
        if self.global_every <= 0:
            return False
        # gemma3 pattern: layers (global_every-1, 2*global_every-1, ...) global.
        return (layer_idx + 1) % self.global_every == 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """MoE layer config — covers the paper's Soft MoE, the sparse baselines
    (Tokens Choice / Experts Choice) and the fixed-routing ablations."""

    # "soft" | "tokens_choice" | "experts_choice" |
    # "identity" | "uniform" | "soft_uniform" | "uniform_soft"
    variant: str = "soft"
    num_experts: int = 8
    expert_d_ff: int = 0  # 0 => use model d_ff
    num_shared_experts: int = 0  # DeepSeek-style always-on experts
    # Soft MoE
    slots_per_expert: int = 1
    # Tokens Choice
    top_k: int = 2
    bpr: bool = True  # Batch Priority Routing (Riquelme et al. 2021)
    # Experts Choice / Tokens Choice capacity
    capacity_factor: float = 1.0
    # Aux losses (sparse variants only; Soft MoE needs none — balanced by
    # construction, which is part of the paper's point).
    aux_loss_weight: float = 0.01
    router_z_loss_weight: float = 1e-3
    # Router group size in sequences (sparse variants; paper §3.5).
    group_size: int = 1
    # Escape hatch: force the training-time batch-coupled group routing
    # (groups of `group_size` sequences compete for per-call capacity
    # buffers) in EVERY mode, serving included. Default False: serving
    # modes ("prefill"/"decode") route each row's tokens independently
    # and droplessly, so a request's outputs never depend on batch
    # composition, chunking, or speculative lookahead (the batch-invariant
    # serving contract; docs/serving.md). Training batches are
    # fixed-composition, so mode="train" always uses the coupled group
    # routing regardless of this flag — the paper's training setup is
    # unchanged.
    batch_coupled: bool = False
    # Fused Pallas kernel policy (Soft MoE, use_kernel=True; see
    # repro.kernels.tuning). 0 = derive block sizes from the (m, d, S)
    # heuristic table; set explicitly to pin a tiling (or autotune).
    kernel_block_tokens: int = 0
    kernel_block_slots: int = 0
    kernel_acc_dtype: str = "float32"  # accumulator/softmax-stat dtype

    def total_slots(self) -> int:
        return self.num_experts * self.slots_per_expert


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB (vlm / audio): input_specs() supplies
    precomputed patch/frame embeddings of dimension `embed_dim` and length
    `num_embeds`, which are linearly projected and prepended / encoded."""

    kind: str = "none"  # "none" | "vision" | "audio"
    embed_dim: int = 0
    num_embeds: int = 0


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # "dense" | "ssm" | "hybrid" | "moe" | "vlm" | "audio" | "vit"
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 128
    d_ff: int = 512
    vocab_size: int = 1024
    max_seq_len: int = 8192
    attention: Optional[AttentionConfig] = None
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    # Which layer indices carry the MoE block ("" = none, "all", "second_half",
    # or comma-separated indices). Paper default: second half of MLP blocks.
    moe_layers: str = ""
    # Hybrid (Hymba): attention and SSM run in PARALLEL inside one block and
    # their outputs are mean-fused.
    hybrid_parallel: bool = False
    # Encoder-decoder (Seamless): number of encoder layers (0 = decoder-only).
    encoder_layers: int = 0
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    act: str = "silu"  # "silu" | "gelu"
    # "gated" (SwiGLU: 3 matmuls, LLM-style) | "classic" (2 matmuls,
    # fc1-act-fc2 — the paper's ViT MLP/expert shape; gives the published
    # 933M for soft-moe-s/16-128e where gated would give 1378M)
    mlp_style: str = "gated"
    tie_embeddings: bool = False
    causal: bool = True
    # Training-time numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    logits_softcap: float = 0.0  # gemma-style final-logit softcap

    # -- derived helpers ---------------------------------------------------
    def moe_layer_indices(self) -> Tuple[int, ...]:
        if not self.moe_layers or self.moe is None:
            return ()
        if self.moe_layers == "all":
            return tuple(range(self.num_layers))
        if self.moe_layers == "second_half":
            return tuple(range(self.num_layers // 2, self.num_layers))
        return tuple(int(i) for i in self.moe_layers.split(","))

    def has_attention(self) -> bool:
        return self.attention is not None

    def has_ssm(self) -> bool:
        return self.ssm is not None

    def supports_long_context(self) -> bool:
        """True if decode at 500k tokens is sub-quadratic / bounded-state."""
        if self.ssm is not None and self.attention is None:
            return True  # pure SSM
        if self.hybrid_parallel:
            return True  # SSM path + (sliding-window) attention
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d = self.d_model
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # unembedding
        n_dec = self.num_layers
        total += self._stack_params(n_dec, cross_attention=self.encoder_layers > 0)
        if self.encoder_layers:
            total += self._stack_params(self.encoder_layers, cross_attention=False)
        if self.frontend.kind != "none":
            total += self.frontend.embed_dim * d  # projection stub
        return total

    def _attn_params(self) -> int:
        a = self.attention
        if a is None:
            return 0
        d = self.d_model
        if a.kind == "mla":
            qk_head = a.qk_nope_head_dim + a.qk_rope_head_dim
            p = d * a.kv_lora_rank  # kv down-proj
            p += d * a.qk_rope_head_dim  # decoupled k_rope proj
            p += a.kv_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)
            if a.q_lora_rank:
                p += d * a.q_lora_rank + a.q_lora_rank * a.num_heads * qk_head
            else:
                p += d * a.num_heads * qk_head
            p += a.num_heads * a.v_head_dim * d  # out proj
            return p
        q = d * a.num_heads * a.head_dim
        kv = 2 * d * a.num_kv_heads * a.head_dim
        o = a.num_heads * a.head_dim * d
        b = (a.num_heads + 2 * a.num_kv_heads) * a.head_dim if a.qkv_bias else 0
        return q + kv + o + b

    def _ssm_params(self) -> int:
        s = self.ssm
        if s is None:
            return 0
        d = self.d_model
        di = s.d_inner(d)
        nh = s.num_heads(d)
        p = d * (2 * di + 2 * s.ngroups * s.state_dim + nh)  # in_proj (z,x,B,C,dt)
        p += s.conv_width * (di + 2 * s.ngroups * s.state_dim)  # conv1d
        p += nh * 2 + di  # A_log, D, dt_bias... (approx: nh + nh + di norm)
        p += di * d  # out_proj
        return p

    def _mlp_params(self, d_ff: int) -> int:
        n_mats = 3 if self.mlp_style == "gated" else 2
        return n_mats * self.d_model * d_ff

    def _moe_params(self) -> int:
        m = self.moe
        assert m is not None
        dff = m.expert_d_ff or self.d_ff
        p = m.num_experts * self._mlp_params(dff)
        p += m.num_shared_experts * self._mlp_params(dff)
        if m.variant == "soft":
            p += self.d_model * m.total_slots() + 1  # Phi + scale
        else:
            p += self.d_model * m.num_experts  # router
        return p

    def _stack_params(self, n_layers: int, cross_attention: bool) -> int:
        moe_idx = set(self.moe_layer_indices())
        total = 0
        for i in range(n_layers):
            total += self._attn_params()
            if cross_attention:
                total += self._attn_params()
            total += self._ssm_params()
            if self.moe is not None and i in moe_idx:
                total += self._moe_params()
            elif self.d_ff > 0:
                total += self._mlp_params(self.d_ff)
            total += 2 * self.d_model  # norms
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-active experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dff = m.expert_d_ff or self.d_ff
        if m.variant == "soft":
            # FLOPs governed by slot count; at slots≈tokens this is ~1 expert
            # per token-equivalent: count top_k=1 expert equivalent.
            active_e = max(1, m.total_slots() * 0 + 1)
        else:
            active_e = m.top_k
        per_layer_inactive = (m.num_experts - active_e - m.num_shared_experts)
        dead = len(self.moe_layer_indices()) * per_layer_inactive * self._mlp_params(dff)
        return self.param_count() - max(dead, 0)


# ---------------------------------------------------------------------------
# Input-shape registry (assigned shapes; identical across LM archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, with skip reason."""
    if shape == "long_500k" and not cfg.supports_long_context():
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is full-attention (see DESIGN.md §5)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Reduced configs for smoke tests
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config: runs a fwd/train step on CPU in seconds."""
    attn = cfg.attention
    if attn is not None:
        heads = min(attn.num_heads, 4)
        ratio = max(1, attn.num_heads // max(attn.num_kv_heads, 1))
        kv = max(1, heads // min(ratio, heads))
        attn = dataclasses.replace(
            attn,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            kv_lora_rank=16 if attn.kind == "mla" else 0,
            q_lora_rank=0,
            qk_rope_head_dim=8 if attn.kind == "mla" else 0,
            qk_nope_head_dim=8 if attn.kind == "mla" else 0,
            v_head_dim=16 if attn.kind == "mla" else 0,
            sliding_window=16 if attn.sliding_window else None,
        )
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(
            ssm, state_dim=16, head_dim=8, chunk_size=16, conv_width=4
        )
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 4),
            expert_d_ff=32,
            top_k=min(moe.top_k, 2),
            num_shared_experts=min(moe.num_shared_experts, 1),
        )
    d_model = 64
    if ssm is not None:
        d_model = max(d_model, ssm.head_dim * 4 * 2 // ssm.expand)
    if attn is not None:
        d_model = max(d_model, attn.num_heads * 4)
    return dataclasses.replace(
        cfg,
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=d_model,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        max_seq_len=128,
        attention=attn,
        ssm=ssm,
        moe=moe,
        moe_layers="second_half" if cfg.moe_layers else "",
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend=dataclasses.replace(cfg.frontend, embed_dim=32, num_embeds=8)
        if cfg.frontend.kind != "none"
        else cfg.frontend,
        scan_layers=False,
        remat=False,
    )
