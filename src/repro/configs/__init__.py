"""Config registry: ``get_config(name)`` / ``list_configs()``.

Names accept an optional ``+soft`` suffix which switches the MoE variant of
an assigned arch to Soft MoE (or adds Soft-MoE layers to a dense arch, paper
placement: second half of blocks) — the paper technique as a first-class,
selectable feature on every architecture where it applies (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from .archs import ASSIGNED
from .base import (  # noqa: F401
    AttentionConfig,
    FrontendConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    reduced,
    shape_supported,
)
from .vit import PAPER_MODELS, soft_moe_vit, vit  # noqa: F401

_REGISTRY = {m.name: m for m in ASSIGNED}
_REGISTRY.update({m.name: m for m in PAPER_MODELS})

ASSIGNED_NAMES = tuple(m.name for m in ASSIGNED)


def softify(cfg: ModelConfig, num_experts: int | None = None) -> ModelConfig:
    """Return the Soft-MoE variant of an arch (paper technique applied)."""
    if cfg.ssm is not None and cfg.attention is None and cfg.d_ff == 0:
        raise ValueError(
            f"{cfg.name}: Soft MoE replaces MLP blocks and this arch has "
            "none (DESIGN.md §5 — inapplicable)."
        )
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, variant="soft",
            num_experts=num_experts or cfg.moe.num_experts,
        )
        layers = cfg.moe_layers
    else:
        moe = MoEConfig(variant="soft", num_experts=num_experts or 128,
                        expert_d_ff=cfg.d_ff)
        layers = "second_half"
    return dataclasses.replace(
        cfg, name=cfg.name + "+soft", moe=moe, moe_layers=layers
    )


def get_config(name: str) -> ModelConfig:
    base, plus, suffix = name.partition("+")
    if base not in _REGISTRY:
        raise KeyError(
            f"unknown arch {base!r}; available: {sorted(_REGISTRY)}"
        )
    cfg = _REGISTRY[base]
    if plus:
        if suffix != "soft":
            raise KeyError(f"unknown variant suffix {suffix!r}")
        cfg = softify(cfg)
    return cfg


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
