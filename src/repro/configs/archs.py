"""The 10 assigned architectures, exact published configs.

Sources are cited per-arch; see DESIGN.md §5 for Soft-MoE applicability.
"""
from __future__ import annotations

from .base import (
    AttentionConfig,
    FrontendConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

# --- dense GQA decoders -----------------------------------------------------

# [arXiv:2407.10671; hf] Qwen2-72B: GQA with QKV bias.
QWEN2_72B = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="gqa", num_heads=64, num_kv_heads=8, head_dim=128,
        qkv_bias=True, rope_theta=1e6,
    ),
    tie_embeddings=False,
)

# [arXiv:2407.10671; hf] Qwen2-0.5B.
QWEN2_0_5B = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151936,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="gqa", num_heads=14, num_kv_heads=2, head_dim=64,
        qkv_bias=True, rope_theta=1e6,
    ),
    tie_embeddings=True,
)

# [arXiv:2407.21783] Llama-3-8B: GQA, 128k vocab.
LLAMA3_8B = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=8, head_dim=128,
        rope_theta=5e5,
    ),
)

# [hf:google/gemma-3] Gemma3-27B: 5:1 local:global sliding-window attention.
GEMMA3_27B = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab_size=262144,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=16, head_dim=128,
        rope_theta=1e6, sliding_window=1024, global_every=6,
    ),
    tie_embeddings=True,
    logits_softcap=30.0,
    act="gelu",
)

# [hf:mistralai/Pixtral-12B-2409] Pixtral-12B: pixtral-ViT frontend (STUB:
# input_specs() supplies precomputed patch embeddings) + mistral-nemo decoder.
PIXTRAL_12B = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    max_seq_len=131072,
    attention=AttentionConfig(
        kind="gqa", num_heads=32, num_kv_heads=8, head_dim=128,
        rope_theta=1e9,
    ),
    frontend=FrontendConfig(kind="vision", embed_dim=1024, num_embeds=256),
)

# [arXiv:2405.21060] Mamba2-370m: pure SSD, attention-free, d_ff=0.
MAMBA2_370M = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,
    vocab_size=50280,
    max_seq_len=1048576,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
    tie_embeddings=True,
    norm="rmsnorm",
)

# [arXiv:2411.13676; hf] Hymba-1.5B: parallel attention + mamba heads per
# block, mean-fused; sliding-window attention on most layers.
HYMBA_1_5B = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    max_seq_len=1048576,
    attention=AttentionConfig(
        kind="gqa", num_heads=25, num_kv_heads=5, head_dim=64,
        sliding_window=1024, global_every=16,
    ),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
    hybrid_parallel=True,
)

# [arXiv:2405.04434; hf] DeepSeek-V2-Lite (16B total): MLA kv_lora=512,
# 2 shared + 64 routed experts top-6, expert d_ff=1408, first layer dense.
DEEPSEEK_V2_LITE = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    d_ff=10944,  # dense layers' MLP width
    vocab_size=102400,
    max_seq_len=163840,
    attention=AttentionConfig(
        kind="mla", num_heads=16, num_kv_heads=16, head_dim=192,
        kv_lora_rank=512, q_lora_rank=0,
        qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    ),
    moe=MoEConfig(
        variant="tokens_choice", num_experts=64, expert_d_ff=1408,
        num_shared_experts=2, top_k=6, capacity_factor=1.0, bpr=False,
    ),
    moe_layers=",".join(str(i) for i in range(1, 27)),  # all but layer 0
)

# [hf:ibm-granite/granite-3.0-1b-a400m-base] Granite MoE: 32 experts top-8.
GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    d_ff=512,
    vocab_size=49155,
    max_seq_len=8192,
    attention=AttentionConfig(
        kind="gqa", num_heads=16, num_kv_heads=8, head_dim=64,
    ),
    moe=MoEConfig(
        variant="tokens_choice", num_experts=32, expert_d_ff=512,
        top_k=8, capacity_factor=1.0, bpr=False,
    ),
    moe_layers="all",
    tie_embeddings=True,
)

# [arXiv:2308.11596] SeamlessM4T-large-v2 backbone: encoder-decoder; audio
# frontend STUB (input_specs() supplies precomputed frame embeddings).
SEAMLESS_M4T_LARGE = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab_size=256206,
    max_seq_len=8192,
    attention=AttentionConfig(
        kind="gqa", num_heads=16, num_kv_heads=16, head_dim=64,
    ),
    frontend=FrontendConfig(kind="audio", embed_dim=1024, num_embeds=512),
    norm="layernorm",
    act="gelu",
)

ASSIGNED = (
    QWEN2_72B,
    QWEN2_0_5B,
    LLAMA3_8B,
    GEMMA3_27B,
    PIXTRAL_12B,
    MAMBA2_370M,
    HYMBA_1_5B,
    DEEPSEEK_V2_LITE,
    GRANITE_MOE_1B,
    SEAMLESS_M4T_LARGE,
)
