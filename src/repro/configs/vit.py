"""The paper's own model family: ViT backbones and their Soft-MoE variants.

Paper (§3): ViT S/16, B/16, L/16, H/14 with the second half of MLP blocks
replaced by Soft MoE layers (128 or 256 experts, one slot per expert).
These are encoders (non-causal), the paper's native domain.
"""
from __future__ import annotations

import dataclasses

from .base import AttentionConfig, FrontendConfig, ModelConfig, MoEConfig

_VIT_DIMS = {
    # name: (layers, d_model, heads, d_ff)
    "s": (12, 384, 6, 1536),
    "b": (12, 768, 12, 3072),
    "l": (24, 1024, 16, 4096),
    "h": (32, 1280, 16, 5120),
}


def vit(size: str, patch: int, image_size: int = 224) -> ModelConfig:
    layers, d, heads, d_ff = _VIT_DIMS[size]
    tokens = (image_size // patch) ** 2
    return ModelConfig(
        name=f"vit-{size}/{patch}",
        family="vit",
        num_layers=layers,
        d_model=d,
        d_ff=d_ff,
        vocab_size=0,  # classifier head attached by the model, not vocab
        max_seq_len=tokens,
        attention=AttentionConfig(
            kind="gqa", num_heads=heads, num_kv_heads=heads,
            head_dim=d // heads,
        ),
        frontend=FrontendConfig(kind="vision", embed_dim=patch * patch * 3,
                                num_embeds=tokens),
        causal=False,
        norm="layernorm",
        act="gelu",
        mlp_style="classic",  # paper ViT/expert MLPs: fc1-gelu-fc2
    )


def soft_moe_vit(size: str, patch: int, num_experts: int,
                 slots_per_expert: int = 1, variant: str = "soft",
                 image_size: int = 224) -> ModelConfig:
    """Paper default: MoE in the second half of blocks, 1 slot/expert."""
    base = vit(size, patch, image_size)
    return dataclasses.replace(
        base,
        name=f"{variant}-moe-{size}/{patch}-{num_experts}e",
        moe=MoEConfig(variant=variant, num_experts=num_experts,
                      slots_per_expert=slots_per_expert),
        moe_layers="second_half",
    )


# The long-run configs from Table 1/2.
SOFT_MOE_S16_128E = soft_moe_vit("s", 16, 128)
SOFT_MOE_S14_256E = soft_moe_vit("s", 14, 256)
SOFT_MOE_B16_128E = soft_moe_vit("b", 16, 128)
SOFT_MOE_L16_128E = soft_moe_vit("l", 16, 128)
SOFT_MOE_H14_128E = soft_moe_vit("h", 14, 128)
SOFT_MOE_H14_256E = soft_moe_vit("h", 14, 256)
VIT_S16 = vit("s", 16)
VIT_B16 = vit("b", 16)
VIT_L16 = vit("l", 16)
VIT_H14 = vit("h", 14)

PAPER_MODELS = (
    VIT_S16, VIT_B16, VIT_L16, VIT_H14,
    SOFT_MOE_S16_128E, SOFT_MOE_S14_256E, SOFT_MOE_B16_128E,
    SOFT_MOE_L16_128E, SOFT_MOE_H14_128E, SOFT_MOE_H14_256E,
)
