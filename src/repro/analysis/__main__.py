"""CLI: ``python -m repro.analysis --all`` (docs/static_analysis.md).

Exit code 0 iff every pass over every selected program is clean modulo
the allowlist; allowlisted findings are printed with their reasons so
the recorded debt stays visible in CI logs.
"""
from __future__ import annotations

import argparse
import sys
import time

from .framework import PASSES, AnalysisReport, run_passes
from .programs import DEFAULT_ALLOWLIST, GRID, build_program_specs, \
    kernel_program_specs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract linter for the serving/training "
                    "stack (jaxpr + lowering + AST passes).",
    )
    ap.add_argument("--all", action="store_true",
                    help="whole arch grid, all passes")
    ap.add_argument("--arch", action="append", default=None,
                    help="restrict to arch(s) (repeatable); "
                         f"grid: {', '.join(GRID)}")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass subset "
                         f"(registered: {', '.join(sorted(PASSES))})")
    ap.add_argument("--no-train", action="store_true",
                    help="skip the train-step program")
    ap.add_argument("--list", action="store_true",
                    help="list archs and passes, then exit")
    args = ap.parse_args(argv)

    if args.list:
        print("archs:", " ".join(GRID))
        print("passes:", " ".join(sorted(PASSES)))
        return 0
    if not args.all and not args.arch:
        ap.error("pick --all or --arch NAME")

    archs = list(args.arch) if args.arch else list(GRID)
    pass_names = args.passes.split(",") if args.passes else sorted(PASSES)

    report = AnalysisReport()
    t0 = time.time()
    # program passes run per arch; host-purity is source-level and runs
    # exactly once at the end
    prog_passes = [p for p in pass_names if p != "host-purity"]
    if prog_passes:
        for i, arch in enumerate(archs):
            print(f"[{i + 1}/{len(archs)}] {arch} ...", flush=True)
            specs = build_program_specs(arch, train=not args.no_train)
            report.merge(
                run_passes(specs, prog_passes, DEFAULT_ALLOWLIST)
            )
        # arch-independent: the Soft-MoE kernel grad program
        report.merge(run_passes(kernel_program_specs(), prog_passes,
                                DEFAULT_ALLOWLIST))
    if "host-purity" in pass_names:
        report.merge(run_passes([], ["host-purity"], DEFAULT_ALLOWLIST))

    print(report.render())
    print(f"({time.time() - t0:.1f}s, {len(archs)} arch(s), "
          f"{len(pass_names)} pass(es))")
    return 0 if report.ok() else 1


if __name__ == "__main__":
    sys.exit(main())
