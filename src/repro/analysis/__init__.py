"""Static contract linter: trace-time proofs of the serving stack's
invariants (docs/static_analysis.md).

Five registered passes over every jitted serving/training program:

* ``materialization`` — no (m × S) Soft-MoE plane, no
  (B, blocks·block_size) paged row view (ShapeRule predicates);
* ``retrace``         — churn never changes a program's trace signature;
* ``donation``        — pool-carrying programs donate their cache
  buffers (read from the lowering's aliasing info);
* ``dtype``           — accumulations agree with the declared
  ``KernelConfig.acc_dtype``;
* ``host-purity``     — AST lint: no host syncs in the tick path, no
  import-scope jit, no import-time backend probes.

CLI: ``python -m repro.analysis --all``. Pytest API: build specs with
``build_program_specs(arch)`` (or hand-rolled ``ProgramSpec`` fixtures)
and run ``run_passes(specs, [...], DEFAULT_ALLOWLIST)``.
"""
from .framework import (  # noqa: F401
    AllowRule,
    AnalysisReport,
    Finding,
    PASSES,
    ProgramSpec,
    ShapeRule,
    apply_allowlist,
    arg_signature,
    iter_jaxprs,
    materialized_shapes,
    register_pass,
    run_passes,
)
from .passes import (  # noqa: F401
    donation_pass,
    dtype_pass,
    host_purity_findings,
    host_purity_pass,
    materialization_pass,
    retrace_pass,
    serve_side_sources,
)
from .programs import (  # noqa: F401
    DEFAULT_ALLOWLIST,
    GRID,
    build_program_specs,
    grid_specs,
    kernel_program_specs,
    serving_program_specs,
    train_program_spec,
)
