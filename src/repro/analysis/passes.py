"""The five contract passes (docs/static_analysis.md has the taxonomy).

Each pass is ``fn(specs) -> (findings, n_checked)`` registered in
``framework.PASSES``. All of them are trace-time / source-level only —
no program is executed, no device buffer is touched.
"""
from __future__ import annotations

import ast
import os
from typing import List, Sequence

import jax
import jax.numpy as jnp

from .framework import (
    Finding,
    ProgramSpec,
    arg_signature,
    iter_jaxprs,
    materialized_shapes,
    register_pass,
)

# ---------------------------------------------------------------------------
# 1. materialization — forbidden intermediate shapes
# ---------------------------------------------------------------------------


@register_pass("materialization")
def materialization_pass(specs: Sequence[ProgramSpec]):
    """No jaxpr of a spec with ``forbid`` rules may contain an
    intermediate matching any rule — the (m × S) Soft-MoE plane
    (PAPER.md §2's linear-memory claim) and the (B, blocks·block_size)
    paged row view are both instances of this one predicate."""
    findings: List[Finding] = []
    n = 0
    for spec in specs:
        if not spec.forbid:
            continue
        n += 1
        jaxpr = spec.jaxpr()
        for rule in spec.forbid:
            shapes = materialized_shapes(jaxpr.jaxpr, rule)
            if shapes:
                findings.append(Finding(
                    "materialization", spec.label,
                    f"{rule.label} materialized: shapes {shapes}",
                ))
    return findings, n


# ---------------------------------------------------------------------------
# 2. retrace — one trace signature per program under churn
# ---------------------------------------------------------------------------


@register_pass("retrace")
def retrace_pass(specs: Sequence[ProgramSpec]):
    """Every churn variant of a program's arguments must produce the same
    jit cache key (pytree structure + per-leaf shape/dtype/weakness).
    This is the static half of the runtime ``jit_cache_sizes`` assertion:
    churn changes VALUES, never signatures, so each program compiles
    exactly once for the engine's lifetime."""
    findings: List[Finding] = []
    n = 0
    for spec in specs:
        if not spec.churn:
            continue
        n += 1
        base = arg_signature(spec.args)
        for i, variant in enumerate(spec.churn):
            sig = arg_signature(variant)
            if sig != base:
                diffs = _signature_diff(base, sig)
                findings.append(Finding(
                    "retrace", spec.label,
                    f"churn variant {i} changes the trace signature "
                    f"({diffs}) — this program would recompile under "
                    "churn",
                ))
    return findings, n


def _signature_diff(a, b) -> str:
    if a[0] != b[0]:
        return "pytree structure differs"
    out = []
    for j, (la, lb) in enumerate(zip(a[1], b[1])):
        if la != lb:
            out.append(f"leaf {j}: {la} -> {lb}")
    return "; ".join(out) or "unknown"


# ---------------------------------------------------------------------------
# 3. donation — pool buffers must alias in place
# ---------------------------------------------------------------------------


@register_pass("donation")
def donation_pass(specs: Sequence[ProgramSpec]):
    """Every argnum in ``spec.donate`` must be donated in the lowered
    program (input/output aliasing), read back from jax's own
    ``lowered.args_info`` — the compiled truth, not the python source.
    A pool-carrying program that forgets ``donate_argnums`` silently
    doubles its cache's memory on accelerators."""
    findings: List[Finding] = []
    n = 0
    for spec in specs:
        if not spec.donate:
            continue
        if not spec.jitted:
            findings.append(Finding(
                "donation", spec.label,
                "program expects donation but is not jitted",
            ))
            continue
        n += 1
        # args_info mirrors the (args, kwargs) call structure
        pos_info = spec.lowered().args_info[0]
        for argnum in spec.donate:
            leaves = jax.tree_util.tree_leaves(pos_info[argnum])
            bad = [str(getattr(info, "_aval", "?")) for info in leaves
                   if not info.donated]
            if bad:
                findings.append(Finding(
                    "donation", spec.label,
                    f"argnum {argnum} not donated "
                    f"({len(bad)}/{len(leaves)} leaves, e.g. {bad[0]}) — "
                    "missing donate_argnums",
                ))
    return findings, n


# ---------------------------------------------------------------------------
# 4. dtype — accumulation dtype discipline
# ---------------------------------------------------------------------------

_REDUCE_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce",  # generic lax.reduce — jnp.sum upcasts, lax.reduce won't
    "cumsum", "cumlogsumexp", "cummax", "cummin",
}


@register_pass("dtype")
def dtype_pass(specs: Sequence[ProgramSpec]):
    """Accumulation regions must agree with the declared
    ``KernelConfig.acc_dtype``:

    * every floating-point reduction (sum/max/min/prod/cumulative) must
      accumulate in exactly ``acc_dtype`` — a bf16 reduction is a silent
      precision loss, an f64 one a silent upcast;
    * no dot_general may emit a dtype narrower than its widest floating
      operand (bf16×bf16→bf16 is fine — the MXU accumulates f32
      internally and the declared output is bf16 — but f32×f32→bf16
      would silently discard accumulated precision).

    ``dtype_policy="dots_only"`` skips the reduction rule — the train
    step's backward legitimately reduce-sums bf16 cotangents when
    transposing broadcasts (gradient dtype == forward compute dtype).
    """
    findings: List[Finding] = []
    n = 0
    for spec in specs:
        if spec.dtype_policy == "skip":
            continue
        n += 1
        acc = jnp.dtype(spec.acc_dtype)
        reduce_bad = {}
        dot_bad = {}
        for j in iter_jaxprs(spec.jaxpr().jaxpr):
            for eqn in j.eqns:
                prim = eqn.primitive.name
                if (prim in _REDUCE_PRIMS
                        and spec.dtype_policy == "strict"):
                    out = eqn.outvars[0].aval
                    dt = getattr(out, "dtype", None)
                    if (dt is not None
                            and jnp.issubdtype(dt, jnp.floating)
                            and dt != acc):
                        key = (prim, str(dt))
                        reduce_bad[key] = reduce_bad.get(key, 0) + 1
                elif prim == "dot_general":
                    fl = [v.aval.dtype for v in eqn.invars
                          if jnp.issubdtype(v.aval.dtype, jnp.floating)]
                    out_dt = eqn.outvars[0].aval.dtype
                    if (fl and jnp.issubdtype(out_dt, jnp.floating)
                            and out_dt.itemsize
                            < max(d.itemsize for d in fl)):
                        key = (str(fl), str(out_dt))
                        dot_bad[key] = dot_bad.get(key, 0) + 1
        for (prim, dt), count in sorted(reduce_bad.items()):
            word = "downcast" if jnp.dtype(dt).itemsize < acc.itemsize \
                else "upcast"
            findings.append(Finding(
                "dtype", spec.label,
                f"{count}× {prim} accumulates in {dt} ({word}), declared "
                f"acc_dtype is {spec.acc_dtype}",
            ))
        for (operands, out_dt), count in sorted(dot_bad.items()):
            findings.append(Finding(
                "dtype", spec.label,
                f"{count}× dot_general {operands} -> {out_dt} discards "
                "accumulated precision below its widest operand",
            ))
    return findings, n


# ---------------------------------------------------------------------------
# 5. host-purity — AST lint over serve-side python
# ---------------------------------------------------------------------------

_SYNC_METHODS = {"item", "block_until_ready"}
_SYNC_JAX_FUNCS = {"device_get", "block_until_ready"}
_IMPORT_TIME_BACKEND = {"default_backend", "devices", "local_devices",
                        "device_count"}


def _dotted(node) -> str:
    """'jax.jit' for Attribute(Name('jax'), 'jit'); '' if not a plain
    dotted name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _HostPurityVisitor(ast.NodeVisitor):
    """Three rules over one file:

    R1 anywhere: no host syncs — ``.item()`` / ``.block_until_ready()``
       method calls, ``jax.device_get`` / ``jax.block_until_ready``
       calls. Any of these inside the engine tick serializes the device
       pipeline; the telemetry drain is the one sanctioned sync point
       (allowlisted, not exempted here).
    R2 import scope: no ``jax.jit(...)`` outside function bodies — an
       import-scope jit builds its cache before any config exists and
       pins it for every later caller.
    R3 import scope: no backend probes (``jax.default_backend()``,
       ``jax.devices()``, ...) outside function bodies — an import-time
       "interpret" global freezes the backend choice at import order
       (the bug kernels/tuning.py documents removing).
    """

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self.depth = 0  # function nesting; 0 == import scope

    def _flag(self, node, msg):
        self.findings.append(Finding(
            "host-purity", f"{self.path}:{node.lineno}", msg
        ))

    def visit_FunctionDef(self, node):
        # decorators run at the enclosing scope: @jax.jit (bare or via
        # functools.partial) on a module-level def is an import-scope jit
        if self.depth == 0:
            for dec in node.decorator_list:
                if any(_dotted(sub) == "jax.jit"
                       for sub in ast.walk(dec)):
                    self._flag(dec, "jax.jit at import scope (decorator)")
                    break
        for dec in node.decorator_list:
            self.visit(dec)
        node.decorator_list = []
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    def visit_Call(self, node):
        name = _dotted(node.func)
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            if attr in _SYNC_METHODS and not name.startswith("jax."):
                self._flag(node, f".{attr}() is a host sync")
            if (name.startswith("jax.")
                    and name.split(".")[-1] in _SYNC_JAX_FUNCS):
                self._flag(node, f"{name}() is a host sync")
            if self.depth == 0:
                tail = name.split(".")[-1]
                if name == "jax.jit":
                    self._flag(node, "jax.jit at import scope")
                elif (name.startswith("jax.")
                        and tail in _IMPORT_TIME_BACKEND):
                    self._flag(
                        node,
                        f"{name}() at import scope freezes the backend "
                        "choice at import time",
                    )
        self.generic_visit(node)


def host_purity_findings(paths: Sequence[str]) -> List[Finding]:
    """Run the host-purity AST lint over explicit file paths (the
    fixture-facing entry; the registered pass lints the serve stack)."""
    findings: List[Finding] = []
    for path in paths:
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        rel = os.path.relpath(path, _repo_root()) \
            if os.path.isabs(path) else path
        visitor = _HostPurityVisitor(rel)
        visitor.visit(tree)
        findings.extend(visitor.findings)
    return findings


def _repo_root() -> str:
    # src/repro/analysis/passes.py -> repo root
    return os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..")
    )


def serve_side_sources() -> List[str]:
    """The host-purity scan surface: the engine/serving modules plus the
    kernel tuning layer (where the import-time interpret global once
    lived)."""
    root = _repo_root()
    out = []
    for sub in ("src/repro/serve", "src/repro/kernels"):
        d = os.path.join(root, sub)
        out.extend(
            os.path.join(d, f) for f in sorted(os.listdir(d))
            if f.endswith(".py")
        )
    return out


@register_pass("host-purity")
def host_purity_pass(specs: Sequence[ProgramSpec]):
    paths = serve_side_sources()
    return host_purity_findings(paths), len(paths)
