"""Program inventory: every jitted serving/training program as a
``ProgramSpec``.

The specs harvest the REAL jitted callables off constructed backends,
engines and trainers (``be._decode``, ``eng._sample``,
``trainer.train_step``, ...) — never re-declarations — so the linter
checks exactly what production executes and cannot drift from it.
Argument tuples mirror the call-site conversions byte for byte
(``jnp.int32(slot)``, ``jnp.asarray([toks], jnp.int32)``, host numpy
sampling params, ...); churn variants change VALUES the way request
churn does (slots, tables, padding, liveness) and must never change the
trace signature.

Geometry: ``batch=3`` rows, ``max_len=112`` (7 blocks × 16), spec lanes
``k+1=4``. 3 and 112 are distinct from every `reduced()` model axis
(d_model 64+, vocab 256, heads ≤4, head_dim 16, d_ff 128) so the
(B, blocks·block_size) ShapeRule can only match the paged row view —
the same dim-disjointness argument ``benchmarks/bench_kernels.py``
documents. ``_check_dims`` enforces it against the actual param/cache
avals instead of assuming.
"""
from __future__ import annotations

import tempfile
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ASSIGNED_NAMES, get_config, reduced
from ..models import build_model, lm_init
from .framework import AllowRule, ProgramSpec, ShapeRule

GRID = ASSIGNED_NAMES

BATCH = 3
MAX_LEN = 112
BLOCK_SIZE = 16
SPEC_K = 3  # verify lanes = k+1 = 4


# ---------------------------------------------------------------------------
# The allowlist: every intentional exception, with its reason
# ---------------------------------------------------------------------------

DEFAULT_ALLOWLIST = (
    AllowRule(
        "donation", "engine/sample@*",
        "the sampler reads the engine's persistent logits buffer "
        "non-destructively; the same buffer feeds the next prefill/"
        "decode write after sampling, so donating it would free live "
        "engine state",
    ),
    AllowRule(
        "materialization", "paged/verify@*",
        "S>1 programs take the jnp gather path — the Pallas paged-"
        "attention kernel is single-query; tracked by the ROADMAP "
        "'ragged paged-attention kernel family' item",
    ),
    AllowRule(
        "materialization", "paged/prefill_chunk@*",
        "chunked prefill (S>1) takes the jnp gather path — same "
        "ROADMAP 'ragged paged-attention kernel family' item as verify",
    ),
    AllowRule(
        "materialization", "paged/decode@deepseek-v2-lite-16b",
        "MLA absorbed-form decode keeps the gather path (the paged "
        "kernel covers GQA only — layers/attention.py documents it); "
        "the MLA latent-pool kernel variant is in the same ROADMAP item",
    ),
    AllowRule(
        "host-purity", "src/repro/kernels/tuning.py:*",
        "autotune's timing harness must block_until_ready around the "
        "candidate it times — it runs offline (bench/startup), never "
        "inside the engine tick",
    ),
    AllowRule(
        "host-purity", "src/repro/serve/telemetry.py:*",
        "TelemetryAggregator.drain is the engine's one sanctioned "
        "device->host sync point — called once per tick AFTER the "
        "tokens of the tick are committed (docs/observability.md)",
    ),
)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _check_dims(label: str, trees, rules: tuple):
    """Assert no param/cache leaf is itself flagged by a ShapeRule — the
    dim-disjointness precondition of the shape predicates (a model
    tensor that legitimately carries BOTH marker dims would make the
    rule vacuously noisy)."""
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            shape = tuple(np.shape(leaf))
            for rule in rules:
                if shape and rule.flags(shape):
                    raise ValueError(
                        f"{label}: model tensor of shape {shape} "
                        f"collides with rule {rule.label!r}; pick "
                        "different geometry"
                    )


def _row_view_rule(batch: int, view_len: int) -> ShapeRule:
    return ShapeRule(
        (batch,), (view_len,),
        f"({batch} × {view_len}) paged row-view gather",
    )


def _i32(x):
    return jnp.asarray(np.asarray(x, np.int32))


# ---------------------------------------------------------------------------
# serving programs
# ---------------------------------------------------------------------------


def serving_program_specs(arch: str, batch: int = BATCH,
                          max_len: int = MAX_LEN,
                          block_size: int = BLOCK_SIZE
                          ) -> List[ProgramSpec]:
    """Specs for every jitted program of one arch's contiguous AND paged
    engines (+ sampler, + speculative accept where supported)."""
    from ..serve.engine import ServeEngine
    from ..serve.spec_decode import SpecConfig

    cfg = reduced(get_config(arch))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    spec_ok = cfg.attention is not None and cfg.ssm is None
    eng_c = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                        backend="contiguous")
    eng_p = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                        backend="paged", block_size=block_size,
                        spec=SpecConfig(k=SPEC_K) if spec_ok else None)
    cb, pb = eng_c.backend, eng_p.backend

    view_len = pb.blocks_per_row * block_size
    _check_dims(f"{arch} serving geometry", (params, pb.cache),
                (_row_view_rule(batch, view_len),
                 _row_view_rule(1, view_len)))

    V = cfg.vocab_size
    lanes = SPEC_K + 1
    buf = jnp.zeros((batch, V), jnp.float32)
    specs: List[ProgramSpec] = []

    # -- contiguous ---------------------------------------------------------
    chunk_c = min(32, cb.max_chunk)

    def chunk_args(slot, fill, pad=0):
        toks = [0] * pad + [fill] * (chunk_c - pad)
        poss = [-1] * pad + list(range(chunk_c - pad))
        return (jnp.int32(slot), jnp.asarray([toks], jnp.int32),
                jnp.asarray([poss], jnp.int32))

    specs.append(ProgramSpec(
        "contiguous/prefill_chunk", arch, cb._prefill_chunk,
        (params, cb.pool.cache, buf) + chunk_args(0, 1),
        churn=(
            (params, cb.pool.cache, buf) + chunk_args(2, 7),
            (params, cb.pool.cache, buf) + chunk_args(1, 3, pad=5),
        ),
        donate=(1, 2),
        acc_dtype="float32",
    ))

    def decode_args(toks, pos):
        return (params, jnp.asarray(np.asarray(toks, np.int32)),
                jnp.asarray(np.asarray(pos, np.int32)), cb.pool.cache)

    specs.append(ProgramSpec(
        "contiguous/decode", arch, cb._decode,
        decode_args([[1]] * batch, [4] * batch),
        churn=(
            decode_args([[7], [2], [9]], [10, 3, 55]),
            decode_args([[0]] * batch, [-1, 6, -1]),  # inactive rows
        ),
        donate=(3,),
        acc_dtype="float32",
    ))

    def cverify_args(pos):
        toks = np.ones((batch, lanes), np.int32)
        return (params, jnp.asarray(toks),
                jnp.asarray(np.asarray(pos, np.int32)), cb.pool.cache)

    specs.append(ProgramSpec(
        "contiguous/verify", arch, cb._verify,
        cverify_args([[5, 6, 7, 8]] * batch),
        churn=(cverify_args([[5, 6, -1, -1], [1, -1, -1, -1],
                             [9, 10, 11, -1]]),),
        donate=(3,),
        acc_dtype="float32",
    ))

    specs.append(ProgramSpec(
        "contiguous/invalidate", arch, cb._invalidate,
        (cb.pool.cache, jnp.asarray(np.full((batch, lanes), 6, np.int32))),
        churn=((cb.pool.cache,
                jnp.asarray(np.full((batch, lanes), -1, np.int32))),),
        donate=(0,),
        dtype_policy="skip",  # pure scatter, no accumulation
    ))

    specs.append(ProgramSpec(
        "contiguous/clear_slot", arch, cb.pool._clear,
        (cb.pool.cache, jnp.int32(0)),
        churn=((cb.pool.cache, jnp.int32(batch - 1)),),
        donate=(0,),
        dtype_policy="skip",
    ))

    # -- paged --------------------------------------------------------------
    chunk_p = min(32, pb.max_chunk)
    table1 = jnp.asarray(np.arange(1, pb.blocks_per_row + 1,
                                   dtype=np.int32)[None])
    tables = jnp.asarray(
        np.arange(1, batch * pb.blocks_per_row + 1,
                  dtype=np.int32).reshape(batch, pb.blocks_per_row))

    def pchunk_args(slot, fill, pad=0):
        toks = [0] * pad + [fill] * (chunk_p - pad)
        poss = [-1] * pad + list(range(chunk_p - pad))
        return (params, pb.cache, buf, jnp.int32(slot), table1,
                jnp.asarray([toks], jnp.int32),
                jnp.asarray([poss], jnp.int32))

    specs.append(ProgramSpec(
        "paged/prefill_chunk", arch, pb._prefill_chunk,
        pchunk_args(0, 1),
        churn=(pchunk_args(2, 7), pchunk_args(1, 3, pad=9)),
        donate=(1, 2),
        forbid=((_row_view_rule(1, view_len),)
                if cfg.attention is not None else ()),
        acc_dtype="float32",
    ))

    def pdecode_args(toks, pos):
        return (params, jnp.asarray(np.asarray(toks, np.int32)),
                jnp.asarray(np.asarray(pos, np.int32)), tables, pb.cache)

    specs.append(ProgramSpec(
        "paged/decode", arch, pb._decode,
        pdecode_args([[1]] * batch, [4] * batch),
        churn=(
            pdecode_args([[7], [2], [9]], [10, 3, 55]),
            pdecode_args([[0]] * batch, [-1, 6, -1]),
        ),
        donate=(4,),
        forbid=((_row_view_rule(batch, view_len),)
                if cfg.attention is not None else ()),
        acc_dtype="float32",
        notes="the PR 4 no-row-view kernel proof, generalized",
    ))

    def pverify_args(pos):
        toks = np.ones((batch, lanes), np.int32)
        return (params, jnp.asarray(toks),
                jnp.asarray(np.asarray(pos, np.int32)), tables, pb.cache)

    specs.append(ProgramSpec(
        "paged/verify", arch, pb._verify,
        pverify_args([[5, 6, 7, 8]] * batch),
        churn=(pverify_args([[5, -1, -1, -1], [1, 2, -1, -1],
                             [9, 10, 11, -1]]),),
        donate=(4,),
        forbid=((_row_view_rule(batch, view_len),)
                if cfg.attention is not None else ()),
        acc_dtype="float32",
    ))

    specs.append(ProgramSpec(
        "paged/invalidate", arch, pb._invalidate,
        (pb.cache, jnp.asarray(np.full((batch, lanes), 6, np.int32)),
         tables),
        churn=((pb.cache,
                jnp.asarray(np.full((batch, lanes), -1, np.int32)),
                tables),),
        donate=(0,),
        dtype_policy="skip",
    ))

    ids = jnp.asarray(np.arange(1, 9, dtype=np.int32))
    ids2 = jnp.asarray(np.full((8,), pb.num_blocks, np.int32))  # all pad
    specs.append(ProgramSpec(
        "paged/clear_blocks", arch, pb._clear_blocks,
        (pb.cache, ids), churn=((pb.cache, ids2),),
        donate=(0,), dtype_policy="skip",
    ))
    specs.append(ProgramSpec(
        "paged/copy_blocks", arch, pb._copy_blocks,
        (pb.cache, ids, ids2), churn=((pb.cache, ids2, ids),),
        donate=(0,), dtype_policy="skip",
    ))
    if pb._clear_ssm is not None:
        specs.append(ProgramSpec(
            "paged/clear_ssm", arch, pb._clear_ssm,
            (pb.cache, jnp.int32(0)),
            churn=((pb.cache, jnp.int32(batch - 1)),),
            donate=(0,), dtype_policy="skip",
        ))

    # -- engine-level -------------------------------------------------------
    def sample_args(temp, step):
        return (
            buf,
            np.asarray(temp, np.float32),
            np.zeros((batch,), np.int32),
            np.ones((batch,), np.float32),
            np.zeros((batch,), np.int32),
            np.asarray(step, np.int32),
        )

    specs.append(ProgramSpec(
        "engine/sample", arch, eng_c._sample,
        sample_args([0.0] * batch, [0] * batch),
        churn=(sample_args([0.7, 0.0, 2.0], [3, 0, 9]),),
        donate=(0,),  # intentionally NOT donated -> allowlist entry
        acc_dtype="float32",
    ))

    if eng_p._spec is not None:
        sd = eng_p._spec
        k = SPEC_K

        def accept_args(n_draft, temp):
            logits = jnp.zeros((batch, lanes, V), jnp.float32)
            drafts = jnp.asarray(np.ones((batch, k), np.int32))
            return (logits, drafts,
                    jnp.asarray(np.asarray(n_draft, np.int32)),
                    np.asarray(temp, np.float32),
                    np.zeros((batch,), np.int32),
                    np.ones((batch,), np.float32),
                    np.zeros((batch,), np.int32),
                    np.zeros((batch,), np.int32))

        specs.append(ProgramSpec(
            "engine/spec_accept", arch, sd._accept,
            accept_args([k] * batch, [0.0] * batch),
            churn=(accept_args([0, 1, k], [0.5, 0.0, 1.5]),),
            acc_dtype="float32",
        ))
        specs.append(ProgramSpec(
            "engine/spec_finite", arch, sd._finite,
            (buf,), churn=((jnp.ones((batch, V), jnp.float32),),),
            dtype_policy="skip",  # pure isfinite reduction over bools
        ))

    return specs


# ---------------------------------------------------------------------------
# training program
# ---------------------------------------------------------------------------


def train_program_spec(arch: str) -> List[ProgramSpec]:
    """The Trainer's own jitted train step (value_and_grad + AdamW).

    ``dtype_policy="dots_only"``: the backward legitimately reduce-sums
    bf16 cotangents when transposing broadcasts (gradient dtype follows
    the forward compute dtype), so the strict reduction rule applies to
    serving programs only — the dot-downcast rule still holds here.
    """
    from ..data import SyntheticLM, SyntheticSeq2Seq
    from ..optim import OptimizerConfig
    from ..train import Trainer, TrainerConfig
    from ..train.step import init_train_state

    cfg = reduced(get_config(arch))
    init_fn, loss_fn, _ = build_model(cfg)
    if cfg.encoder_layers > 0:
        data = SyntheticSeq2Seq(
            vocab_size=cfg.vocab_size, seq_len=16,
            num_frames=cfg.frontend.num_embeds,
            frame_dim=cfg.frontend.embed_dim, batch_size=2,
        )
    else:
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           batch_size=2)
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(
            TrainerConfig(total_steps=1, checkpoint_dir=d),
            loss_fn, init_fn, OptimizerConfig(total_steps=1), data,
        )
    state = init_train_state(jax.random.PRNGKey(0), init_fn)
    return [ProgramSpec(
        "train/step", arch, trainer.train_step,
        (state, data.batch(0)),
        churn=((state, data.batch(1)),),
        donate=(0,),
        acc_dtype="float32",
        dtype_policy="dots_only",
    )]


# ---------------------------------------------------------------------------
# Soft-MoE kernel program (the paper's (m × S) claim, fwd + bwd)
# ---------------------------------------------------------------------------


def kernel_program_specs() -> List[ProgramSpec]:
    """The fused Soft-MoE train path: grad of a kernel-routed loss must
    carry no (m × S) plane in EITHER direction — the generalized form of
    `benchmarks.bench_kernels.check_materialization` (dims pairwise
    distinct: m=320, d=160, s=48, d_ff=224, b=3)."""
    from ..configs.base import MoEConfig
    from ..core import moe_apply, moe_init
    from ..kernels.tuning import config_from_moe

    m, d, n, b = 320, 160, 48, 3
    cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=224)
    s = n * cfg.slots_per_expert
    params = moe_init(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, m, d))
    kc = config_from_moe(cfg, m=m, d=d)
    m_pad = -(-m // kc.block_tokens) * kc.block_tokens
    s_pad = -(-s // kc.block_slots) * kc.block_slots

    def loss(p):
        return (moe_apply(p, cfg, x, use_kernel=True)[0] ** 2).mean()

    rule = ShapeRule(
        (m, m_pad), (s, s_pad),
        f"(m × S) Soft-MoE plane (m={m}/{m_pad}, s={s}/{s_pad})",
    )
    return [ProgramSpec(
        "kernels/soft_moe_grad", "soft-moe", jax.grad(loss), (params,),
        forbid=(rule,),
        acc_dtype=kc.acc_dtype,
        dtype_policy="dots_only",  # bwd cotangent sums follow x's dtype
        notes="PAPER.md §2 linear-memory claim, fwd+bwd",
    )]


# ---------------------------------------------------------------------------
# top-level inventory
# ---------------------------------------------------------------------------


def build_program_specs(arch: str, train: bool = True) -> List[ProgramSpec]:
    """Full spec list for one arch (serving + train step)."""
    specs = serving_program_specs(arch)
    if train:
        specs += train_program_spec(arch)
    return specs


def grid_specs(archs: Optional[List[str]] = None,
               train: bool = True,
               progress=None) -> List[ProgramSpec]:
    """Specs for the whole arch grid plus the arch-independent Soft-MoE
    kernel program."""
    specs: List[ProgramSpec] = []
    for arch in archs or GRID:
        if progress:
            progress(f"building specs: {arch}")
        specs += build_program_specs(arch, train=train)
    specs += kernel_program_specs()
    return specs
