"""Pass-registry core of the contract linter (docs/static_analysis.md).

The framework is deliberately small: a pass is a function
``(specs, ctx) -> [Finding]`` registered under a name; a ``ProgramSpec``
describes ONE jitted program (the real jitted object harvested off a
live backend/engine, never a re-declaration that could drift) together
with example arguments, churn variants, donation expectations, forbidden
shape predicates and the declared accumulation dtype; an ``Allowlist``
turns intentional exceptions into recorded, reasoned findings instead of
failures. Everything is trace-time only — no program is ever executed.

The single jaxpr walker lives here (``iter_jaxprs`` /
``materialized_shapes``); `benchmarks/bench_kernels.py` wraps it, so the
CI materialization proof and this linter cannot diverge.
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import jax
import numpy as np

try:  # jax >= 0.4.16
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr


# ---------------------------------------------------------------------------
# The one jaxpr walker
# ---------------------------------------------------------------------------


def iter_jaxprs(jaxpr):
    """Yield `jaxpr` and every sub-jaxpr reachable through eqn params
    (pjit bodies, scan/while/cond branches, custom_vjp closures, pallas
    kernel jaxprs, ...). This is THE repo's jaxpr walker — every
    materialization/dtype proof goes through it."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            leaves = jax.tree_util.tree_leaves(
                v, is_leaf=lambda x: isinstance(x, (Jaxpr, ClosedJaxpr))
            )
            for leaf in leaves:
                if isinstance(leaf, ClosedJaxpr):
                    yield from iter_jaxprs(leaf.jaxpr)
                elif isinstance(leaf, Jaxpr):
                    yield from iter_jaxprs(leaf)


@dataclass(frozen=True)
class ShapeRule:
    """Forbid any intermediate whose shape has a dim from `dims_a` AND a
    dim from `dims_b` (e.g. the (m × S) Soft-MoE plane, the
    (B, blocks·block_size) paged row view). Padded extents ride along in
    the same sets."""

    dims_a: tuple
    dims_b: tuple
    label: str

    def flags(self, shape) -> bool:
        return (
            any(d in shape for d in self.dims_a)
            and any(d in shape for d in self.dims_b)
        )


def materialized_shapes(jaxpr, rule: ShapeRule) -> List[tuple]:
    """All distinct eqn-output shapes in `jaxpr` (and sub-jaxprs) flagged
    by `rule`, sorted. Empty list == the predicate is proven absent."""
    hits = set()
    for j in iter_jaxprs(jaxpr):
        for eqn in j.eqns:
            for var in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(var, "aval", None)
                shape = tuple(getattr(aval, "shape", ()))
                if shape and rule.flags(shape):
                    hits.add(shape)
    return sorted(hits)


# ---------------------------------------------------------------------------
# Argument signatures (the retrace contract)
# ---------------------------------------------------------------------------


def arg_signature(args: tuple):
    """Hashable stand-in for jax's jit cache key over `args`: pytree
    structure + per-leaf (shape, dtype, weak). Two argument tuples with
    equal signatures hit the same compiled executable; a signature change
    under churn IS a retrace."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    sig = []
    for leaf in leaves:
        if isinstance(leaf, (bool, int, float, complex)):
            # python scalars are weak-typed jit cache keys
            sig.append(("py", type(leaf).__name__))
        else:
            dtype = getattr(leaf, "dtype", None)
            sig.append((tuple(np.shape(leaf)), str(dtype),
                        bool(getattr(leaf, "weak_type", False))))
    return (str(treedef), tuple(sig))


# ---------------------------------------------------------------------------
# Program inventory
# ---------------------------------------------------------------------------


@dataclass
class ProgramSpec:
    """One jitted program + the contracts the passes check it against.

    ``fn`` must be the REAL jitted callable the serving/training stack
    executes (harvested off a constructed backend/engine) so the linter
    can never drift from production behavior. ``args`` is one full
    example argument tuple; ``churn`` holds alternative tuples that model
    request churn (different slots, tables, positions, padding) and must
    all map to the same trace signature. ``donate`` lists argnums whose
    buffers the program must donate (pool state). ``forbid`` lists
    ShapeRules that must not appear in the jaxpr. ``acc_dtype`` +
    ``dtype_policy`` configure the accumulation-dtype pass: "strict"
    (all float reductions in acc_dtype, no dot downcast), "dots_only"
    (reductions unchecked — see the train-step note in
    docs/static_analysis.md), or "skip".
    """

    name: str
    arch: str
    fn: Callable
    args: tuple
    churn: tuple = ()
    donate: tuple = ()
    forbid: tuple = ()
    acc_dtype: str = "float32"
    dtype_policy: str = "strict"
    notes: str = ""
    _jaxpr: Optional[ClosedJaxpr] = field(default=None, repr=False)
    _lowered: object = field(default=None, repr=False)

    @property
    def label(self) -> str:
        return f"{self.name}@{self.arch}"

    @property
    def jitted(self) -> bool:
        return hasattr(self.fn, "lower")

    def jaxpr(self):
        """Traced jaxpr of fn(*args), cached across passes."""
        if self._jaxpr is None:
            self._jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self._jaxpr

    def lowered(self):
        """Lowered (pre-compile) form — carries donation/aliasing info.
        Only meaningful for jitted fns."""
        if self._lowered is None:
            self._lowered = self.fn.lower(*self.args)
        return self._lowered


# ---------------------------------------------------------------------------
# Findings, allowlist, report
# ---------------------------------------------------------------------------


@dataclass
class Finding:
    """One contract violation. ``where`` is a program label
    ("paged/decode@llama3-8b") or a source location
    ("src/repro/serve/x.py:12"); allowlisted findings keep the reason."""

    pass_name: str
    where: str
    message: str
    allowed: bool = False
    reason: str = ""

    def render(self) -> str:
        mark = "ALLOWED" if self.allowed else "FAIL"
        line = f"[{mark}] {self.pass_name}: {self.where}: {self.message}"
        if self.allowed and self.reason:
            line += f"\n          reason: {self.reason}"
        return line


@dataclass(frozen=True)
class AllowRule:
    """Intentional exception: findings of `pass_name` whose ``where``
    fnmatch-es `pattern` are recorded (not failures). Every rule MUST
    carry a reason — that reason is the documentation of the exception."""

    pass_name: str
    pattern: str
    reason: str

    def matches(self, f: Finding) -> bool:
        return (
            f.pass_name == self.pass_name
            and fnmatch.fnmatch(f.where, self.pattern)
        )


def apply_allowlist(findings: Iterable[Finding],
                    allowlist: Sequence[AllowRule]) -> List[Finding]:
    out = []
    for f in findings:
        for rule in allowlist:
            if rule.matches(f):
                f.allowed, f.reason = True, rule.reason
                break
        out.append(f)
    return out


@dataclass
class AnalysisReport:
    findings: List[Finding] = field(default_factory=list)
    checked: Dict[str, int] = field(default_factory=dict)

    @property
    def failures(self) -> List[Finding]:
        return [f for f in self.findings if not f.allowed]

    @property
    def allowed(self) -> List[Finding]:
        return [f for f in self.findings if f.allowed]

    def ok(self) -> bool:
        return not self.failures

    def merge(self, other: "AnalysisReport"):
        self.findings.extend(other.findings)
        for k, v in other.checked.items():
            self.checked[k] = self.checked.get(k, 0) + v

    def render(self) -> str:
        lines = []
        for name in sorted(self.checked):
            n_fail = sum(1 for f in self.failures if f.pass_name == name)
            n_allow = sum(1 for f in self.allowed if f.pass_name == name)
            status = "ok" if n_fail == 0 else f"{n_fail} FAILURES"
            extra = f", {n_allow} allowlisted" if n_allow else ""
            lines.append(
                f"{name}: {self.checked[name]} checked, {status}{extra}"
            )
        for f in self.findings:
            lines.append(f.render())
        verdict = "PASS" if self.ok() else "FAIL"
        lines.append(f"analysis: {verdict}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Pass registry
# ---------------------------------------------------------------------------

PASSES: Dict[str, Callable] = {}


def register_pass(name: str):
    """Register ``fn(specs) -> [Finding]`` under `name`. Adding a pass =
    write the function, decorate it, document it — the CLI, the pytest
    API and ``--passes`` filtering pick it up from this dict."""

    def deco(fn):
        PASSES[name] = fn
        return fn

    return deco


def run_passes(specs: Sequence[ProgramSpec],
               pass_names: Optional[Sequence[str]] = None,
               allowlist: Sequence[AllowRule] = ()) -> AnalysisReport:
    """Run the named passes (default: all registered) over `specs` and
    fold the allowlist in. The pytest-facing entry point."""
    report = AnalysisReport()
    for name in pass_names or sorted(PASSES):
        if name not in PASSES:
            raise KeyError(
                f"unknown pass {name!r}; registered: {sorted(PASSES)}"
            )
        findings, n = PASSES[name](specs)
        report.findings.extend(apply_allowlist(findings, allowlist))
        report.checked[name] = report.checked.get(name, 0) + n
    return report
