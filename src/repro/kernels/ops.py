"""Jitted wrappers for the Soft-MoE kernels.

Forward runs the fused Pallas kernels (interpret=True on CPU — TPU is the
target); backward is a custom_vjp built from the ref.py math (jax.vjp of
the oracle), so training through the kernels is exact w.r.t. Algorithm 1+2.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .soft_moe_kernels import combine_pallas, dispatch_pallas

# CPU container: interpret mode. On TPU this flag flips to False.
INTERPRET = jax.default_backend() != "tpu"


# -- dispatch ---------------------------------------------------------------


@jax.custom_vjp
def soft_moe_dispatch(x, phi_n):
    """x: (b, m, d); phi_n: (d, S) pre-normalized -> slots (b, S, d)."""
    return jax.vmap(lambda xs: dispatch_pallas(xs, phi_n,
                                               interpret=INTERPRET))(x)


def _dispatch_fwd(x, phi_n):
    return soft_moe_dispatch(x, phi_n), (x, phi_n)


def _dispatch_bwd(res, g):
    x, phi_n = res
    _, vjp = jax.vjp(lambda xx, pp: jax.vmap(
        lambda xs: ref.dispatch_ref(xs, pp))(xx), x, phi_n)
    return vjp(g)


soft_moe_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


# -- combine ----------------------------------------------------------------


@jax.custom_vjp
def soft_moe_combine(x, phi_n, ys):
    """x: (b, m, d); phi_n: (d, S); ys: (b, S, d) -> y (b, m, d)."""
    return jax.vmap(
        lambda xs, yss: combine_pallas(xs, phi_n, yss, interpret=INTERPRET)
    )(x, ys)


def _combine_fwd(x, phi_n, ys):
    return soft_moe_combine(x, phi_n, ys), (x, phi_n, ys)


def _combine_bwd(res, g):
    x, phi_n, ys = res
    _, vjp = jax.vjp(
        lambda xx, pp, yy: jax.vmap(
            lambda xs, yss: ref.combine_ref(xs, pp, yss)
        )(xx, yy),
        x, phi_n, ys,
    )
    return vjp(g)


soft_moe_combine.defvjp(_combine_fwd, _combine_bwd)


# -- full layer helper (used by core.soft_moe) -------------------------------


def normalized_phi(phi, scale):
    """phi: (d, n, p) -> (d, n*p) pre-normalized (O(d·S), done outside the
    kernels — X normalization stays inside since X is re-read per pass)."""
    d = phi.shape[0]
    return ref.normalized_phi(phi.reshape(d, -1), scale)
