"""Jitted wrappers for the Soft-MoE kernels: fused forward AND backward.

Forward runs the batched Pallas kernels (one launch covers (b, m, d) via a
leading batch grid axis). Backward is a custom_vjp wired to the
flash-style Pallas backward kernels in soft_moe_kernels.py: dispatch and
combine weights are recomputed tile-wise from the online-softmax
``(max, denom)`` residuals, so no (m × S) logit/weight tensor ever exists
in HBM on either direction — the ref.py math is reproduced exactly
(gradients allclose), just never materialized.

Residual layout per layer (see kernels/README.md):

  routing: (x, phi_n, slots, d_mx, d_den)       — O(b·m·d + b·S·d + b·S)
  combine: (x, phi_n, ys, c_mx, c_den, y)       — O(b·m·d + b·S·d + b·m)

The combine stats flow forward from routing as an explicit output; their
cotangent is identically zero (the softmax VJP's normalizer term is
carried by the −σ/−ρ row corrections inside the backward kernels, exactly
as flash attention treats its saved logsumexp), so both bwd rules drop it.

Interpret policy: evaluated lazily per call via ``KernelConfig`` — never
at import time (the seed's ``INTERPRET`` module global went stale if the
backend was selected after import; see kernels/tuning.py).

Per-sequence invariant (the serving contract): the batch axis is a pure
GRID axis. Every softmax stat the kernels compute — the dispatch
``(max, denom)`` per slot and the combine ``(max, denom)`` per token —
reduces only within one row's (m, S) tile; nothing crosses b. Row i of a
batched launch is bit-comparable to a batch-1 launch of that row, so a
served request's routing cannot depend on its co-batched neighbors
(asserted by the row-independence tests in tests/test_kernels.py; the
single-sequence ref.py oracle is the semantic source of truth).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .soft_moe_kernels import (
    combine_apply_pallas,
    combine_bwd_pallas,
    combine_online_pallas,
    dispatch_bwd_pallas,
    routing_fwd_pallas,
    routing_health_pallas,
)
from .tuning import KernelConfig, backend_is_tpu, default_config


def interpret_default() -> bool:
    """Lazy per-call replacement for the old import-time INTERPRET global."""
    return not backend_is_tpu()


def _resolve(config: Optional[KernelConfig], m: int, d: int,
             s: int) -> KernelConfig:
    if config is not None:
        return config
    return default_config(m, d, s)


# -- routing: dispatch output + combine stats in one logits pass ------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _routing(cfg: KernelConfig, x, phi_n):
    slots, d_stats, c_stats = routing_fwd_pallas(x, phi_n, cfg)
    return slots, d_stats[0], d_stats[1], c_stats[0], c_stats[1]


def _routing_fwd(cfg, x, phi_n):
    slots, (d_mx, d_den), (c_mx, c_den) = routing_fwd_pallas(x, phi_n, cfg)
    return (slots, d_mx, d_den, c_mx, c_den), (x, phi_n, slots, d_mx, d_den)


def _routing_bwd(cfg, res, g):
    x, phi_n, slots, d_mx, d_den = res
    g_slots = g[0]  # all four stats cotangents are identically zero
    dx, dphi = dispatch_bwd_pallas(x, phi_n, g_slots, (d_mx, d_den), slots,
                                   cfg)
    return dx, dphi


_routing.defvjp(_routing_fwd, _routing_bwd)


def soft_moe_routing(x, phi_n, config: Optional[KernelConfig] = None,
                     *, with_d_stats: bool = False):
    """x: (b, m, d); phi_n: (d, S) pre-normalized.

    Returns ``(slots, (c_mx, c_den))``: the dispatched slots (b, S, d) and
    the combine-direction softmax stats (each (b, m)) from the same logits
    pass — hand the stats to :func:`soft_moe_combine` to skip its online
    rescan, and derive the ``max_combine`` metric as ``1 / c_den``.

    ``with_d_stats=True`` additionally returns the dispatch-direction
    per-slot stats: ``(slots, (d_mx, d_den), (c_mx, c_den))``. Both stats
    pairs carry zero cotangents (telemetry/inspection consumers wrap them
    in ``stop_gradient`` anyway); the routing gradient is unchanged.
    """
    b, m, d = x.shape
    cfg = _resolve(config, m, d, phi_n.shape[1])
    slots, d_mx, d_den, c_mx, c_den = _routing(cfg, x, phi_n)
    if with_d_stats:
        return slots, (d_mx, d_den), (c_mx, c_den)
    return slots, (c_mx, c_den)


def routing_health(x, phi_n, d_stats, c_stats,
                   config: Optional[KernelConfig] = None):
    """Fig. 9 routing-health reductions from the saved softmax stats.

    Thin wrapper over :func:`routing_health_pallas`; returns
    ``(disp_entropy (b, S), importance (b, S), comb_entropy (b, m),
    token_contrib (b, m))``. See
    ``core.inspection.routing_health_from_stats`` for the chunked jnp
    equivalent (the oracle used in tests).
    """
    b, m, d = x.shape
    cfg = _resolve(config, m, d, phi_n.shape[1])
    return routing_health_pallas(x, phi_n, d_stats, c_stats, cfg)


def soft_moe_dispatch(x, phi_n, config: Optional[KernelConfig] = None):
    """x: (b, m, d); phi_n: (d, S) pre-normalized -> slots (b, S, d)."""
    return soft_moe_routing(x, phi_n, config)[0]


# -- combine ----------------------------------------------------------------


def _combine_bwd_impl(cfg, res, g):
    x, phi_n, ys, c_mx, c_den, y = res
    return combine_bwd_pallas(x, phi_n, ys, g, (c_mx, c_den), y, cfg)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _combine_stats(cfg: KernelConfig, x, phi_n, ys, c_mx, c_den):
    return combine_apply_pallas(x, phi_n, ys, (c_mx, c_den), cfg)


def _combine_stats_fwd(cfg, x, phi_n, ys, c_mx, c_den):
    y = combine_apply_pallas(x, phi_n, ys, (c_mx, c_den), cfg)
    return y, (x, phi_n, ys, c_mx, c_den, y)


def _combine_stats_bwd(cfg, res, g):
    dx, dphi, dys = _combine_bwd_impl(cfg, res, g)
    c_mx, c_den = res[3], res[4]
    return dx, dphi, dys, jnp.zeros_like(c_mx), jnp.zeros_like(c_den)


_combine_stats.defvjp(_combine_stats_fwd, _combine_stats_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _combine_online(cfg: KernelConfig, x, phi_n, ys):
    return combine_online_pallas(x, phi_n, ys, cfg)[0]


def _combine_online_fwd(cfg, x, phi_n, ys):
    y, (c_mx, c_den) = combine_online_pallas(x, phi_n, ys, cfg)
    return y, (x, phi_n, ys, c_mx, c_den, y)


def _combine_online_bwd(cfg, res, g):
    return _combine_bwd_impl(cfg, res, g)


_combine_online.defvjp(_combine_online_fwd, _combine_online_bwd)


def soft_moe_combine(x, phi_n, ys, c_stats=None,
                     config: Optional[KernelConfig] = None):
    """x: (b, m, d); phi_n: (d, S); ys: (b, S, d) -> y (b, m, d).

    ``c_stats``: optional per-token (max, denom) from
    :func:`soft_moe_routing` — skips the online-softmax rescan (the
    shared-logits path). Without it the kernel derives its own stats.
    """
    b, m, d = x.shape
    cfg = _resolve(config, m, d, phi_n.shape[1])
    if c_stats is None:
        return _combine_online(cfg, x, phi_n, ys)
    c_mx, c_den = c_stats
    return _combine_stats(cfg, x, phi_n, ys, c_mx, c_den)


# -- full layer helper (used by core.soft_moe) -------------------------------


def normalized_phi(phi, scale):
    """phi: (d, n, p) -> (d, n*p) pre-normalized (O(d·S), done outside the
    kernels — X normalization stays inside since X is re-read per pass)."""
    d = phi.shape[0]
    return ref.normalized_phi(phi.reshape(d, -1), scale)
