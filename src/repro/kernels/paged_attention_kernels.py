"""Flash-style Pallas decode-attention kernel over the paged KV pool.

Why a kernel: the jnp paged decode path (`layers.attention._paged_view`)
materializes a per-row (B, blocks_per_row * block_size, ...) KV view in
HBM on EVERY decode step — gather-write the view, then read it all back
in the attend — before masking throws most of it away. That is the same
HBM-traffic sin the PR 1 routing kernels eliminated for Soft-MoE
dispatch/combine, and at serving scale decode attention is pure
bandwidth: the row view triples the bytes touched per step (gather read
+ view write + attend read vs streaming the pool tiles once).

This kernel consumes the block pool **in place**. The grid is
``(batch_row, kv_tile)`` and the block tables ride in as a scalar-
prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the KV
BlockSpec's index map dereferences ``tables[b, tile]`` and the pipeline
DMAs exactly one physical (block_size, kv_heads, head_dim) tile from the
(num_blocks, block_size, ...) pool into VMEM per step — logical order,
no intermediate row view anywhere. Per row the kernel keeps online
softmax state — running (max, denom) per head plus an (heads, v_dim)
accumulator — exactly the flash-attention decode recurrence, and every
masking rule of the gather path is applied *inside* the tile:

  * ``pos < 0`` pool entries (never written / invalidated) drop — the
    reserved null block 0 contributes nothing however often a sparse
    table points at it;
  * causality (``pos <= q_pos``) and the sliding-window term
    ``(pos > q_pos - window) | is_global`` match ``make_mask``;
  * inactive rows (``q_pos < 0``) mask every key; the safe-divide
    emits zeros for them (the engine ignores those logits).

GQA grouping is native: q is viewed as (kv_groups, rep, head_dim) and
both dots batch over the group axis, so K/V tiles are fetched once per
row regardless of the query-head fan-out. MLA decode and chunked-prefill
calls keep the gather fallback (`attention.py` routes only GQA s==1
decode here); the latent-cache kernel is a recorded follow-up.

Tiling: one grid step consumes ``paged_block_kv`` rows of a pool block
(``tuning.paged_config`` — whole block by default, subdivided when
``block_size`` exceeds the VMEM-friendly 128). The last dim of a KV tile
is ``head_dim`` (< 128 on most configs), so lanes are padded on real
TPUs — acceptable for a bandwidth-bound decode kernel whose tiles are
resident for exactly one recurrence step. Validated in interpret mode
against the gather path (CPU CI runs it interpreted via the lazy
``KernelConfig.resolve_interpret`` policy, same as the routing kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import KernelConfig, paged_config

_NEG = -1e30


def _paged_decode_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                         out_ref, acc, mx, den, *, groups, rep, causal,
                         window, is_global, scale, dt):
    """One grid step: fold KV tile ``tables[b, jt]`` into row b's online
    softmax state. Grid (batch, kv_tiles); scratch persists across the
    inner kv_tile axis and re-initializes at tile 0 of each row."""
    b, jt = pl.program_id(0), pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(jt == 0)
    def _init_row():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, _NEG)
        den[...] = jnp.zeros_like(den)

    q = q_ref[0].astype(dt)      # (H, Dk)
    k = k_ref[0].astype(dt)      # (bkv, G, Dk)
    v = v_ref[0].astype(dt)      # (bkv, G, Dv)
    kp = pos_ref[0]              # (bkv,) int32; -1 = invalid
    qp = qpos_ref[b]             # scalar; < 0 = inactive row

    d = q.shape[-1]
    qg = q.reshape(groups, rep, d)
    # logits: (G, rep, bkv) — batch over kv groups, contract head_dim.
    s = scale * jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=dt
    )
    ok = kp >= 0
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & ((kp > qp - window) | is_global)
    s = jnp.where(ok[None, None, :], s, _NEG)

    m_old = mx[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    # Zero masked lanes explicitly: while no valid key has been seen the
    # running max is still _NEG and exp(_NEG - _NEG) would count masked
    # keys as weight 1 — fully-masked (inactive) rows must end with
    # denom 0, not a uniform average.
    p = jnp.where(ok[None, None, :], jnp.exp(s - m_new[..., None]), 0.0)
    den[...] = den[...] * corr + p.sum(axis=-1)
    mx[...] = m_new
    # (G, rep, Dv) += p @ v-tile, batched over groups.
    acc[...] = acc[...] * corr[..., None] + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=dt
    )

    @pl.when(jt == nt - 1)
    def _finish_row():
        # Fully-masked rows (q_pos < 0, or an all-null table) have
        # denom 0: the safe divide returns zeros, never NaN.
        out = acc[...] / jnp.maximum(den[...], 1e-30)[..., None]
        out_ref[0] = out.reshape(groups * rep, -1).astype(out_ref.dtype)


def paged_decode_attend(q, k_pool, v_pool, pos_pool, tables, q_pos, *,
                        causal: bool = True, window: Optional[int] = None,
                        is_global: bool = True,
                        scale: Optional[float] = None,
                        cfg: Optional[KernelConfig] = None):
    """Decode attention straight off the paged pool.

    q: (B, H, Dk) one query per row; k_pool/v_pool:
    (num_blocks, block_size, G, D*) shared physical pool; pos_pool:
    (num_blocks, block_size) int32 positions (-1 invalid); tables:
    (B, blocks_per_row) int32 physical block ids (0 = null block);
    q_pos: (B,) int32 absolute positions (-1 = inactive row).
    Returns (B, H, Dv) in q.dtype. Numerics match gathering the row view
    and running the dense masked softmax (checked in
    tests/test_paged_attention_kernel.py).
    """
    b, h, d = q.shape
    _, block_size, groups, dk = k_pool.shape
    dv = v_pool.shape[-1]
    nb = tables.shape[1]
    assert h % groups == 0, (h, groups)
    rep = h // groups
    scale = scale if scale is not None else 1.0 / float(d) ** 0.5
    cfg = cfg if cfg is not None else paged_config(block_size)
    bkv = cfg.paged_block_kv or block_size
    assert block_size % bkv == 0, (block_size, bkv)
    sub = block_size // bkv
    dt = cfg.acc()

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # tables, q_pos feed the index maps
        grid=(b, nb * sub),
        in_specs=[
            pl.BlockSpec((1, h, dk), lambda b, jt, tables, qpos: (b, 0, 0)),
            pl.BlockSpec(
                (1, bkv, groups, dk),
                lambda b, jt, tables, qpos: (tables[b, jt // sub],
                                             jt % sub, 0, 0),
            ),
            pl.BlockSpec(
                (1, bkv, groups, dv),
                lambda b, jt, tables, qpos: (tables[b, jt // sub],
                                             jt % sub, 0, 0),
            ),
            pl.BlockSpec(
                (1, bkv),
                lambda b, jt, tables, qpos: (tables[b, jt // sub], jt % sub),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, h, dv), lambda b, jt, tables, qpos: (b, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((groups, rep, dv), dt),  # output accumulator
            pltpu.VMEM((groups, rep), dt),      # running max
            pltpu.VMEM((groups, rep), dt),      # running denom
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_decode_kernel, groups=groups, rep=rep, causal=causal,
            window=window, is_global=is_global, scale=scale, dt=dt,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dv), q.dtype),
        interpret=cfg.resolve_interpret(),
    )(tables, q_pos, q, k_pool, v_pool, pos_pool)
