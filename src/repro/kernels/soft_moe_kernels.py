"""Fused Soft-MoE routing Pallas TPU kernels — forward AND backward.

Why a kernel: the jnp path materializes the (m × S) logits in HBM *twice*
(once per softmax direction) plus the two weight tensors — at B/16 scale
(m=4096 tokens, S=4096 slots) that is 4 × 64MB of HBM traffic per layer
per sequence that never needs to exist. Every kernel below streams over
the contraction dimension and keeps only (block × d) tiles resident in
VMEM; the (m × S) logits/weights exist only tile-wise, never in HBM.

Forward (single-pass shared logits):

  * ``routing_fwd``: ONE logits pass produces the dispatch output and both
    softmax directions' statistics. For each slot block it streams token
    blocks, runs the online softmax over TOKENS (the D direction) while
    accumulating the slot mix X~ = DᵀX, and folds the same logits tile
    into running per-token (max, denom) over SLOTS (the C direction).
    The seed kernels computed the identical ``l2norm(X) @ Phi_n`` product
    twice (once in dispatch, once in combine) just to derive each
    direction's statistics — the statistics matmul work is halved.
  * ``combine_apply``: consumes the saved per-token (max, denom), so it
    re-materializes exp-logit tiles with **no online rescaling** and
    weights the expert outputs: Y = C Ys.
  * ``combine_online``: standalone combine (no precomputed stats) that
    additionally EMITS its final (max, denom) — the backward residuals.

Backward (flash-style, the dq/dkv split of flash attention): logits tiles
are recomputed from the saved online-softmax ``(max, denom)`` residuals —
O(m + S) floats per direction instead of the (m × S) softmax re-derivation
``jax.vjp``-of-ref would do. Softmax VJP per direction:

  dispatch  dL = D ⊙ (dD − σ),  σ_s = g_s · X~_s        (rowdot of grads
  combine   dL = C ⊙ (dC − ρ),  ρ_i = g_i · Y_i          and fwd outputs)

  * ``dispatch_bwd_dx`` / ``combine_bwd_dx``: token-block major, slot
    blocks inner; accumulate dX (plus the raw D·g term and the l2-norm
    chain applied once at the end of the row of blocks).
  * ``dispatch_bwd_dphi`` / ``combine_bwd_dys_dphi``: slot-block major
    OUTERMOST with (batch, token) inner so the dPhi tile accumulates over
    batch AND tokens in consecutive grid steps (one VMEM-resident
    accumulator, one HBM write per slot block).

Batching: one kernel launch covers (b, m, d) via a leading batch grid
axis (no ``jax.vmap`` over ``pallas_call``); the phi tile's index map
ignores the batch axis, so phi blocks are fetched once and reused across
the batch. The batch grid axis is PURELY parallel — every online-softmax
accumulator (dispatch per-slot and combine per-token (max, denom)) is
indexed by b and reduces only over that row's tokens/slots, so each
sequence's routing is computed exactly as if it were served alone. This
is the per-sequence normalization invariant batch-invariant serving
leans on (ref.py single-sequence oracle; row-independence tests in
tests/test_kernels.py).

Tiling: d stays whole inside a block (the dot needs full rows); token and
slot block sizes come from ``tuning.KernelConfig`` (defaults 128 — minor
dims multiples of 128 for MXU alignment). See ``kernels/README.md`` for
the VMEM budget per kernel, the residual layout, and a block-size table.

Phi arrives pre-normalized (scale * l2norm(phi) is O(d·S), done once
outside); X is l2-normalized inside the kernel (it is re-read every pass —
normalizing outside would double-read X from HBM).

Validated in interpret mode against ref.py (CPU has no MXU; TPU is the
target), forward allclose and gradients allclose to the ref VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import KernelConfig

_NEG = -1e30
_EPS = 1e-6  # must match ref.l2_normalize


def _l2n(x, eps=_EPS):
    norm = jnp.sqrt(jnp.sum(jnp.square(x), axis=1, keepdims=True))
    return x * (1.0 / (norm + eps))


def _dot(a, b, dims, dt):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=dt)


def _logits_tile(x_ref, phi_ref, dt):
    """(x block, phi block) -> (raw x, l2norm x, logits) tiles in acc dtype."""
    x = x_ref[0].astype(dt)  # (bt, d)
    xn = _l2n(x)
    phi = phi_ref[...].astype(dt)  # (d, bs)
    logits = _dot(xn, phi, ((1,), (0,)), dt)  # (bt, bs)
    return x, xn, logits


def _l2n_bwd(x, dxn, dt):
    """VJP of _l2n at raw-token tile x: dX given d(l2norm X)."""
    n = jnp.sqrt(jnp.sum(jnp.square(x), axis=1))  # (bt,)
    r = 1.0 / (n + _EPS)
    inv_n = jnp.where(n > 0, 1.0 / jnp.maximum(n, _EPS), jnp.zeros_like(n))
    proj = jnp.sum(x * dxn, axis=1)  # (bt,)
    return r[:, None] * dxn - (r * r * inv_n * proj)[:, None] * x


def _pad_to(x, size, axis, value=0.0):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _grid_sizes(m, s, cfg: KernelConfig):
    bt, bs = cfg.block_tokens, cfg.block_slots
    return bt, bs, pl.cdiv(m, bt) * bt, pl.cdiv(s, bs) * bs


# ---------------------------------------------------------------------------
# forward: single-pass routing (dispatch output + both directions' stats)
# ---------------------------------------------------------------------------


def _routing_fwd_kernel(x_ref, phi_ref, slots_ref, dmx_ref, dden_ref,
                        cmx_ref, cden_ref, acc, smx, sden, cmx_all, cden_all,
                        *, m_valid, s_valid, bt, bs, dt):
    js, jt = pl.program_id(1), pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init_slot_block():
        acc[...] = jnp.zeros_like(acc)
        smx[...] = jnp.full_like(smx, _NEG)
        sden[...] = jnp.zeros_like(sden)

    tok = pl.ds(jt * bt, bt)

    @pl.when(js == 0)
    def _init_token_stats():
        cmx_all[tok] = jnp.full((bt,), _NEG, dt)
        cden_all[tok] = jnp.zeros((bt,), dt)

    x, _xn, logits = _logits_tile(x_ref, phi_ref, dt)
    row = jt * bt + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    col = js * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    lg_d = jnp.where(row < m_valid, logits, _NEG)  # dispatch: mask pad tokens
    lg_c = jnp.where(col < s_valid, logits, _NEG)  # combine: mask pad slots

    # dispatch direction: online softmax over tokens (inner jt loop)
    m_old = smx[...]
    m_new = jnp.maximum(m_old, lg_d.max(axis=0))  # (bs,)
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(lg_d - m_new[None, :])  # (bt, bs)
    sden[...] = sden[...] * corr + p.sum(axis=0)
    # acc: (bs, d) += pᵀ @ x  (raw x — the paper mixes unnormalized tokens)
    acc[...] = acc[...] * corr[:, None] + _dot(p, x, ((0,), (0,)), dt)
    smx[...] = m_new

    # combine direction: online (max, denom) over slots (outer js loop);
    # running values land in the full-length O(m) scratch and are written
    # out every visit (the (jb, jt) output block is revisited per js, so
    # the buffer cannot be trusted to persist — last write wins).
    cm_old = cmx_all[tok]
    cm_new = jnp.maximum(cm_old, lg_c.max(axis=1))  # (bt,)
    ccorr = jnp.exp(cm_old - cm_new)
    cden_new = cden_all[tok] * ccorr + jnp.exp(
        lg_c - cm_new[:, None]).sum(axis=1)
    cmx_all[tok] = cm_new
    cden_all[tok] = cden_new
    cmx_ref[0] = cm_new.astype(cmx_ref.dtype)
    cden_ref[0] = cden_new.astype(cden_ref.dtype)

    @pl.when(jt == nt - 1)
    def _finish_slot_block():
        slots_ref[0] = (acc[...] / sden[...][:, None]).astype(slots_ref.dtype)
        dmx_ref[0] = smx[...].astype(dmx_ref.dtype)
        dden_ref[0] = sden[...].astype(dden_ref.dtype)


def routing_fwd_pallas(x, phi_n, cfg: KernelConfig = KernelConfig()):
    """x: (b, m, d); phi_n: (d, S) pre-normalized.

    Returns ``(slots, (d_mx, d_den), (c_mx, c_den))`` with slots (b, S, d),
    dispatch stats (b, S) and combine stats (b, m) — one logits pass.
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    bt, bs, m_pad, s_pad = _grid_sizes(m, s, cfg)
    dt = cfg.acc()
    x = _pad_to(x, m_pad, axis=1)
    phi_n = _pad_to(phi_n, s_pad, axis=1)
    grid = (b, s_pad // bs, m_pad // bt)
    out_shapes = (
        jax.ShapeDtypeStruct((b, s_pad, d), x.dtype),  # slots
        jax.ShapeDtypeStruct((b, s_pad), dt),  # dispatch max
        jax.ShapeDtypeStruct((b, s_pad), dt),  # dispatch denom
        jax.ShapeDtypeStruct((b, m_pad), dt),  # combine max
        jax.ShapeDtypeStruct((b, m_pad), dt),  # combine denom
    )
    slots, dmx, dden, cmx, cden = pl.pallas_call(
        functools.partial(_routing_fwd_kernel, m_valid=m, s_valid=s,
                          bt=bt, bs=bs, dt=dt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda jb, js, jt: (jb, jt, 0)),
            pl.BlockSpec((d, bs), lambda jb, js, jt: (0, js)),
        ],
        out_specs=(
            pl.BlockSpec((1, bs, d), lambda jb, js, jt: (jb, js, 0)),
            pl.BlockSpec((1, bs), lambda jb, js, jt: (jb, js)),
            pl.BlockSpec((1, bs), lambda jb, js, jt: (jb, js)),
            pl.BlockSpec((1, bt), lambda jb, js, jt: (jb, jt)),
            pl.BlockSpec((1, bt), lambda jb, js, jt: (jb, jt)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((bs, d), dt),  # slot-mix accumulator
            pltpu.VMEM((bs,), dt),  # dispatch running max
            pltpu.VMEM((bs,), dt),  # dispatch running denom
            pltpu.VMEM((m_pad,), dt),  # combine running max (all tokens)
            pltpu.VMEM((m_pad,), dt),  # combine running denom (all tokens)
        ],
        interpret=cfg.resolve_interpret(),
    )(x, phi_n)
    return (slots[:, :s], (dmx[:, :s], dden[:, :s]),
            (cmx[:, :m], cden[:, :m]))


# ---------------------------------------------------------------------------
# routing health: Fig. 9 statistics from the saved softmax stats
# ---------------------------------------------------------------------------


def _routing_health_kernel(x_ref, phi_ref, dmx_ref, dden_ref, cmx_ref,
                           cden_ref, dent_ref, imp_ref, cent_ref,
                           contrib_ref, dent_acc, imp_acc, cent_all,
                           contrib_all, *, m_valid, s_valid, bt, bs, dt):
    js, jt = pl.program_id(1), pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init_slot_block():
        dent_acc[...] = jnp.zeros_like(dent_acc)
        imp_acc[...] = jnp.zeros_like(imp_acc)

    tok = pl.ds(jt * bt, bt)

    @pl.when(js == 0)
    def _init_token_acc():
        cent_all[tok] = jnp.zeros((bt,), dt)
        contrib_all[tok] = jnp.zeros((bt,), dt)

    _x, _xn, logits = _logits_tile(x_ref, phi_ref, dt)
    row = jt * bt + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    col = js * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid_col = col < s_valid
    lg_d = jnp.where(row < m_valid, logits, _NEG)
    lg_c = jnp.where(valid_col, logits, _NEG)

    # dispatch direction: exact weights from the saved per-slot (max, denom)
    # — log-weights come straight from the shifted logits, so the w·log w
    # entropy terms never take log(0) (masked entries: w = exp(-1e30) = 0
    # times a finite shifted logit). Pad-slot columns carry garbage weights
    # (their stats are (0, 1) padding); they are masked out of the
    # token-contribution row sums and sliced off the per-slot outputs.
    ln_d = lg_d - dmx_ref[0][None, :] - jnp.log(dden_ref[0])[None, :]
    d_w = jnp.exp(ln_d)
    dent_acc[...] = dent_acc[...] - jnp.sum(d_w * ln_d, axis=0)
    contrib_new = contrib_all[tok] + jnp.sum(
        jnp.where(valid_col, d_w, 0.0), axis=1)
    contrib_all[tok] = contrib_new
    contrib_ref[0] = contrib_new.astype(contrib_ref.dtype)

    # combine direction: per-token entropy (full-length scratch, written out
    # every visit — last write wins, same as the fwd kernel's stats) and
    # per-slot importance (pad-token rows masked out of the column sums).
    ln_c = lg_c - cmx_ref[0][:, None] - jnp.log(cden_ref[0])[:, None]
    c_w = jnp.exp(ln_c)
    cent_new = cent_all[tok] - jnp.sum(c_w * ln_c, axis=1)
    cent_all[tok] = cent_new
    cent_ref[0] = cent_new.astype(cent_ref.dtype)
    imp_acc[...] = imp_acc[...] + jnp.sum(
        jnp.where(row < m_valid, c_w, 0.0), axis=0)

    @pl.when(jt == nt - 1)
    def _finish_slot_block():
        dent_ref[0] = dent_acc[...].astype(dent_ref.dtype)
        imp_ref[0] = imp_acc[...].astype(imp_ref.dtype)


def routing_health_pallas(x, phi_n, d_stats, c_stats,
                          cfg: KernelConfig = KernelConfig()):
    """Routing-health statistics for telemetry/inspection (paper Fig. 9).

    Recomputes logits tile-wise against the saved online-softmax
    ``(max, denom)`` residuals — the backward kernels' trick — and reduces
    them in one pass to O(m + S) outputs; the (m × S) weight tensors never
    exist in HBM:

    Returns ``(disp_entropy (b, S), importance (b, S), comb_entropy (b, m),
    token_contrib (b, m))`` — per-slot dispatch-softmax entropy over
    tokens, per-slot combine mass (column sums of C — normalizing by its
    min gives the expert importance spread), per-token combine-softmax
    entropy over slots, and per-token dispatch mass (row sums of D — the
    paper's token contribution; zero means a dropped token, which Soft MoE
    forbids by construction).
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    bt, bs, m_pad, s_pad = _grid_sizes(m, s, cfg)
    dt = cfg.acc()
    dmx, dden = d_stats
    cmx, cden = c_stats
    x = _pad_to(x, m_pad, axis=1)
    phi_n = _pad_to(phi_n, s_pad, axis=1)
    # (max=0, denom=1) stat padding keeps every padded tile finite; padded
    # rows/columns are masked out of all four reductions above.
    dmx = _pad_to(dmx.astype(dt), s_pad, axis=1)
    dden = _pad_to(dden.astype(dt), s_pad, axis=1, value=1.0)
    cmx = _pad_to(cmx.astype(dt), m_pad, axis=1)
    cden = _pad_to(cden.astype(dt), m_pad, axis=1, value=1.0)
    sstat = pl.BlockSpec((1, bs), lambda jb, js, jt: (jb, js))
    tstat = pl.BlockSpec((1, bt), lambda jb, js, jt: (jb, jt))
    dent, imp, cent, contrib = pl.pallas_call(
        functools.partial(_routing_health_kernel, m_valid=m, s_valid=s,
                          bt=bt, bs=bs, dt=dt),
        grid=(b, s_pad // bs, m_pad // bt),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda jb, js, jt: (jb, jt, 0)),
            pl.BlockSpec((d, bs), lambda jb, js, jt: (0, js)),
            sstat, sstat, tstat, tstat,
        ],
        out_specs=(sstat, sstat, tstat, tstat),
        out_shape=(
            jax.ShapeDtypeStruct((b, s_pad), dt),
            jax.ShapeDtypeStruct((b, s_pad), dt),
            jax.ShapeDtypeStruct((b, m_pad), dt),
            jax.ShapeDtypeStruct((b, m_pad), dt),
        ),
        scratch_shapes=[
            pltpu.VMEM((bs,), dt),  # dispatch entropy accumulator
            pltpu.VMEM((bs,), dt),  # combine importance accumulator
            pltpu.VMEM((m_pad,), dt),  # combine entropy (all tokens)
            pltpu.VMEM((m_pad,), dt),  # token contribution (all tokens)
        ],
        interpret=cfg.resolve_interpret(),
    )(x, phi_n, dmx, dden, cmx, cden)
    return dent[:, :s], imp[:, :s], cent[:, :m], contrib[:, :m]


# ---------------------------------------------------------------------------
# forward: combine  y = C Ys   (stats-given and online variants)
# ---------------------------------------------------------------------------


def _combine_kernel(x_ref, phi_ref, ys_ref, cmx_ref, cden_ref, out_ref,
                    *rest, s_valid, bs, dt, online):
    if online:  # emits final stats instead of consuming them
        out_ref, cmx_out, cden_out = cmx_ref, cden_ref, out_ref
        acc, mx, den = rest
    else:
        (acc,) = rest
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        if online:
            mx[...] = jnp.full_like(mx, _NEG)
            den[...] = jnp.zeros_like(den)

    _x, _xn, logits = _logits_tile(x_ref, phi_ref, dt)
    col = js * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    lg_c = jnp.where(col < s_valid, logits, _NEG)
    ys = ys_ref[0].astype(dt)  # (bs, d)

    if online:
        m_old = mx[...]
        m_new = jnp.maximum(m_old, lg_c.max(axis=1))  # (bt,)
        corr = jnp.exp(m_old - m_new)
        p = jnp.exp(lg_c - m_new[:, None])
        den[...] = den[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + _dot(p, ys, ((1,), (0,)), dt)
        mx[...] = m_new
    else:
        # exact final (max, denom) saved by routing_fwd: p ≤ 1, no rescale
        p = jnp.exp(lg_c - cmx_ref[0][:, None])
        acc[...] = acc[...] + _dot(p, ys, ((1,), (0,)), dt)

    @pl.when(js == ns - 1)
    def _finish():
        d_final = den[...] if online else cden_ref[0].astype(dt)
        out_ref[0] = (acc[...] / d_final[:, None]).astype(out_ref.dtype)
        if online:
            cmx_out[0] = mx[...].astype(cmx_out.dtype)
            cden_out[0] = den[...].astype(cden_out.dtype)


def _combine_call(x, phi_n, ys, c_stats, cfg: KernelConfig):
    b, m, d = x.shape
    s = phi_n.shape[1]
    bt, bs, m_pad, s_pad = _grid_sizes(m, s, cfg)
    dt = cfg.acc()
    online = c_stats is None
    x = _pad_to(x, m_pad, axis=1)
    phi_n = _pad_to(phi_n, s_pad, axis=1)
    ys = _pad_to(ys, s_pad, axis=1)
    grid = (b, m_pad // bt, s_pad // bs)
    in_specs = [
        pl.BlockSpec((1, bt, d), lambda jb, jt, js: (jb, jt, 0)),
        pl.BlockSpec((d, bs), lambda jb, jt, js: (0, js)),
        pl.BlockSpec((1, bs, d), lambda jb, jt, js: (jb, js, 0)),
    ]
    stat_spec = pl.BlockSpec((1, bt), lambda jb, jt, js: (jb, jt))
    y_spec = pl.BlockSpec((1, bt, d), lambda jb, jt, js: (jb, jt, 0))
    y_shape = jax.ShapeDtypeStruct((b, m_pad, d), x.dtype)
    if online:
        out = pl.pallas_call(
            functools.partial(_combine_kernel, s_valid=s, bs=bs, dt=dt,
                              online=True),
            grid=grid,
            in_specs=in_specs,
            out_specs=(y_spec, stat_spec, stat_spec),
            out_shape=(y_shape,
                       jax.ShapeDtypeStruct((b, m_pad), dt),
                       jax.ShapeDtypeStruct((b, m_pad), dt)),
            scratch_shapes=[
                pltpu.VMEM((bt, d), dt),
                pltpu.VMEM((bt,), dt),
                pltpu.VMEM((bt,), dt),
            ],
            interpret=cfg.resolve_interpret(),
        )(x, phi_n, ys)
        y, cmx, cden = out
        return y[:, :m], (cmx[:, :m], cden[:, :m])
    cmx, cden = c_stats
    cmx = _pad_to(cmx.astype(dt), m_pad, axis=1)
    cden = _pad_to(cden.astype(dt), m_pad, axis=1, value=1.0)
    y = pl.pallas_call(
        functools.partial(_combine_kernel, s_valid=s, bs=bs, dt=dt,
                          online=False),
        grid=grid,
        in_specs=in_specs + [stat_spec, stat_spec],
        out_specs=y_spec,
        out_shape=y_shape,
        scratch_shapes=[pltpu.VMEM((bt, d), dt)],
        interpret=cfg.resolve_interpret(),
    )(x, phi_n, ys, cmx, cden)
    return y[:, :m], c_stats


def combine_apply_pallas(x, phi_n, ys, c_stats,
                         cfg: KernelConfig = KernelConfig()):
    """Combine with precomputed per-token stats from routing_fwd."""
    return _combine_call(x, phi_n, ys, c_stats, cfg)[0]


def combine_online_pallas(x, phi_n, ys, cfg: KernelConfig = KernelConfig()):
    """Standalone combine; returns (y, (c_mx, c_den)) — stats are the
    backward residuals."""
    return _combine_call(x, phi_n, ys, None, cfg)


# ---------------------------------------------------------------------------
# backward: dispatch  (dX token-major; dPhi slot-major)
# ---------------------------------------------------------------------------


def _dispatch_bwd_tile(x_ref, phi_ref, g_ref, dmx_ref, dden_ref, sig_ref,
                       *, jt, m_valid, bt, dt):
    """Shared tile math: recompute D from residual stats, softmax-VJP."""
    x, xn, logits = _logits_tile(x_ref, phi_ref, dt)
    row = jt * bt + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    lg_d = jnp.where(row < m_valid, logits, _NEG)
    d_w = jnp.exp(lg_d - dmx_ref[0][None, :]) / dden_ref[0][None, :]
    g = g_ref[0].astype(dt)  # (bs, d)
    d_dw = _dot(x, g, ((1,), (1,)), dt)  # (bt, bs) = x · g_s
    d_lg = d_w * (d_dw - sig_ref[0][None, :])
    return x, xn, d_w, d_lg, g


def _dispatch_bwd_dx_kernel(x_ref, phi_ref, g_ref, dmx_ref, dden_ref,
                            sig_ref, dx_ref, acc_raw, acc_n,
                            *, m_valid, bt, dt):
    jt, js = pl.program_id(1), pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        acc_raw[...] = jnp.zeros_like(acc_raw)
        acc_n[...] = jnp.zeros_like(acc_n)

    x, _xn, d_w, d_lg, g = _dispatch_bwd_tile(
        x_ref, phi_ref, g_ref, dmx_ref, dden_ref, sig_ref,
        jt=jt, m_valid=m_valid, bt=bt, dt=dt)
    acc_raw[...] = acc_raw[...] + _dot(d_w, g, ((1,), (0,)), dt)  # D @ g
    phi = phi_ref[...].astype(dt)
    acc_n[...] = acc_n[...] + _dot(d_lg, phi, ((1,), (1,)), dt)  # dL @ phiᵀ

    @pl.when(js == ns - 1)
    def _finish():
        dx = acc_raw[...] + _l2n_bwd(x, acc_n[...], dt)
        dx_ref[0] = dx.astype(dx_ref.dtype)


def _dispatch_bwd_dphi_kernel(x_ref, phi_ref, g_ref, dmx_ref, dden_ref,
                              sig_ref, dphi_ref, acc_p, *, m_valid, bt, dt):
    jb, jt = pl.program_id(1), pl.program_id(2)
    nb, nt = pl.num_programs(1), pl.num_programs(2)

    @pl.when((jb == 0) & (jt == 0))
    def _init():
        acc_p[...] = jnp.zeros_like(acc_p)

    _x, xn, _d_w, d_lg, _g = _dispatch_bwd_tile(
        x_ref, phi_ref, g_ref, dmx_ref, dden_ref, sig_ref,
        jt=jt, m_valid=m_valid, bt=bt, dt=dt)
    acc_p[...] = acc_p[...] + _dot(xn, d_lg, ((0,), (0,)), dt)  # xnᵀ @ dL

    @pl.when((jb == nb - 1) & (jt == nt - 1))
    def _finish():
        dphi_ref[...] = acc_p[...].astype(dphi_ref.dtype)


def dispatch_bwd_pallas(x, phi_n, g_slots, d_stats, slots,
                        cfg: KernelConfig = KernelConfig()):
    """Flash backward of routing/dispatch. Returns (dx, dphi_n).

    x: (b, m, d); phi_n: (d, S); g_slots/slots: (b, S, d);
    d_stats: per-slot (max, denom), each (b, S).
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    bt, bs, m_pad, s_pad = _grid_sizes(m, s, cfg)
    dt = cfg.acc()
    dmx, dden = d_stats
    # σ_s = g_s · X~_s — the dispatch softmax-VJP row term, O(S·d) outside
    # the kernel (never (m × S)).
    sigma = jnp.sum(g_slots.astype(dt) * slots.astype(dt), axis=-1)  # (b, S)
    x_p = _pad_to(x, m_pad, axis=1)
    phi_p = _pad_to(phi_n, s_pad, axis=1)
    g_p = _pad_to(g_slots, s_pad, axis=1)
    # pad stats with (max=0, denom=1): padded-column D tiles stay finite and
    # are multiplied only by zero-padded g/σ, so they never contribute.
    dmx_p = _pad_to(dmx.astype(dt), s_pad, axis=1)
    dden_p = _pad_to(dden.astype(dt), s_pad, axis=1, value=1.0)
    sig_p = _pad_to(sigma, s_pad, axis=1)
    args = (x_p, phi_p, g_p, dmx_p, dden_p, sig_p)

    x_spec_t = pl.BlockSpec((1, bt, d), lambda jb, jt, js: (jb, jt, 0))
    sstat_t = pl.BlockSpec((1, bs), lambda jb, jt, js: (jb, js))
    dx = pl.pallas_call(
        functools.partial(_dispatch_bwd_dx_kernel, m_valid=m, bt=bt, dt=dt),
        grid=(b, m_pad // bt, s_pad // bs),
        in_specs=[
            x_spec_t,
            pl.BlockSpec((d, bs), lambda jb, jt, js: (0, js)),
            pl.BlockSpec((1, bs, d), lambda jb, jt, js: (jb, js, 0)),
            sstat_t, sstat_t, sstat_t,
        ],
        out_specs=x_spec_t,
        out_shape=jax.ShapeDtypeStruct((b, m_pad, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), dt), pltpu.VMEM((bt, d), dt)],
        interpret=cfg.resolve_interpret(),
    )(*args)

    sstat_s = pl.BlockSpec((1, bs), lambda js, jb, jt: (jb, js))
    dphi = pl.pallas_call(
        functools.partial(_dispatch_bwd_dphi_kernel, m_valid=m, bt=bt, dt=dt),
        grid=(s_pad // bs, b, m_pad // bt),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda js, jb, jt: (jb, jt, 0)),
            pl.BlockSpec((d, bs), lambda js, jb, jt: (0, js)),
            pl.BlockSpec((1, bs, d), lambda js, jb, jt: (jb, js, 0)),
            sstat_s, sstat_s, sstat_s,
        ],
        out_specs=pl.BlockSpec((d, bs), lambda js, jb, jt: (0, js)),
        out_shape=jax.ShapeDtypeStruct((d, s_pad), phi_n.dtype),
        scratch_shapes=[pltpu.VMEM((d, bs), dt)],
        interpret=cfg.resolve_interpret(),
    )(*args)
    return dx[:, :m], dphi[:, :s]


# ---------------------------------------------------------------------------
# backward: combine  (dX token-major; dYs + dPhi slot-major)
# ---------------------------------------------------------------------------


def _combine_bwd_tile(x_ref, phi_ref, ys_ref, g_ref, cmx_ref, cden_ref,
                      rho_ref, *, js, s_valid, bs, dt):
    """Shared tile math: recompute C from residual stats, softmax-VJP."""
    _x, xn, logits = _logits_tile(x_ref, phi_ref, dt)
    col = js * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    lg_c = jnp.where(col < s_valid, logits, _NEG)
    c_w = jnp.exp(lg_c - cmx_ref[0][:, None]) / cden_ref[0][:, None]
    g = g_ref[0].astype(dt)  # (bt, d)
    ys = ys_ref[0].astype(dt)  # (bs, d)
    d_cw = _dot(g, ys, ((1,), (1,)), dt)  # (bt, bs) = g_i · ys_s
    d_lg = c_w * (d_cw - rho_ref[0][:, None])
    return xn, c_w, d_lg, g


def _combine_bwd_dx_kernel(x_ref, phi_ref, ys_ref, g_ref, cmx_ref, cden_ref,
                           rho_ref, dx_ref, acc_n, *, s_valid, bs, dt):
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        acc_n[...] = jnp.zeros_like(acc_n)

    _xn, _c_w, d_lg, _g = _combine_bwd_tile(
        x_ref, phi_ref, ys_ref, g_ref, cmx_ref, cden_ref, rho_ref,
        js=js, s_valid=s_valid, bs=bs, dt=dt)
    phi = phi_ref[...].astype(dt)
    acc_n[...] = acc_n[...] + _dot(d_lg, phi, ((1,), (1,)), dt)

    @pl.when(js == ns - 1)
    def _finish():
        x = x_ref[0].astype(dt)
        dx_ref[0] = _l2n_bwd(x, acc_n[...], dt).astype(dx_ref.dtype)


def _combine_bwd_dys_dphi_kernel(x_ref, phi_ref, ys_ref, g_ref, cmx_ref,
                                 cden_ref, rho_ref, dys_ref, dphi_ref,
                                 acc_y, acc_p, *, s_valid, bs, dt):
    js, jb, jt = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nb, nt = pl.num_programs(1), pl.num_programs(2)

    @pl.when(jt == 0)
    def _init_dys():
        acc_y[...] = jnp.zeros_like(acc_y)

    @pl.when((jb == 0) & (jt == 0))
    def _init_dphi():
        acc_p[...] = jnp.zeros_like(acc_p)

    xn, c_w, d_lg, g = _combine_bwd_tile(
        x_ref, phi_ref, ys_ref, g_ref, cmx_ref, cden_ref, rho_ref,
        js=js, s_valid=s_valid, bs=bs, dt=dt)
    acc_y[...] = acc_y[...] + _dot(c_w, g, ((0,), (0,)), dt)  # Cᵀ @ g
    acc_p[...] = acc_p[...] + _dot(xn, d_lg, ((0,), (0,)), dt)  # xnᵀ @ dL

    @pl.when(jt == nt - 1)
    def _finish_dys():
        dys_ref[0] = acc_y[...].astype(dys_ref.dtype)

    @pl.when((jb == nb - 1) & (jt == nt - 1))
    def _finish_dphi():
        dphi_ref[...] = acc_p[...].astype(dphi_ref.dtype)


def combine_bwd_pallas(x, phi_n, ys, g, c_stats, y,
                       cfg: KernelConfig = KernelConfig()):
    """Flash backward of combine. Returns (dx, dphi_n, dys).

    x/g/y: (b, m, d); phi_n: (d, S); ys: (b, S, d);
    c_stats: per-token (max, denom), each (b, m).
    """
    b, m, d = x.shape
    s = phi_n.shape[1]
    bt, bs, m_pad, s_pad = _grid_sizes(m, s, cfg)
    dt = cfg.acc()
    cmx, cden = c_stats
    # ρ_i = g_i · Y_i — the combine softmax-VJP row term, O(m·d) outside.
    rho = jnp.sum(g.astype(dt) * y.astype(dt), axis=-1)  # (b, m)
    x_p = _pad_to(x, m_pad, axis=1)
    phi_p = _pad_to(phi_n, s_pad, axis=1)
    ys_p = _pad_to(ys, s_pad, axis=1)
    g_p = _pad_to(g, m_pad, axis=1)
    # (max=0, denom=1) padding keeps padded-row C tiles finite; they meet
    # only zero-padded g/ρ rows, so dL and every accumulator stay exact.
    cmx_p = _pad_to(cmx.astype(dt), m_pad, axis=1)
    cden_p = _pad_to(cden.astype(dt), m_pad, axis=1, value=1.0)
    rho_p = _pad_to(rho, m_pad, axis=1)
    args = (x_p, phi_p, ys_p, g_p, cmx_p, cden_p, rho_p)

    x_spec_t = pl.BlockSpec((1, bt, d), lambda jb, jt, js: (jb, jt, 0))
    tstat_t = pl.BlockSpec((1, bt), lambda jb, jt, js: (jb, jt))
    dx = pl.pallas_call(
        functools.partial(_combine_bwd_dx_kernel, s_valid=s, bs=bs, dt=dt),
        grid=(b, m_pad // bt, s_pad // bs),
        in_specs=[
            x_spec_t,
            pl.BlockSpec((d, bs), lambda jb, jt, js: (0, js)),
            pl.BlockSpec((1, bs, d), lambda jb, jt, js: (jb, js, 0)),
            x_spec_t, tstat_t, tstat_t, tstat_t,
        ],
        out_specs=x_spec_t,
        out_shape=jax.ShapeDtypeStruct((b, m_pad, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), dt)],
        interpret=cfg.resolve_interpret(),
    )(*args)

    x_spec_s = pl.BlockSpec((1, bt, d), lambda js, jb, jt: (jb, jt, 0))
    tstat_s = pl.BlockSpec((1, bt), lambda js, jb, jt: (jb, jt))
    dys, dphi = pl.pallas_call(
        functools.partial(_combine_bwd_dys_dphi_kernel, s_valid=s, bs=bs,
                          dt=dt),
        grid=(s_pad // bs, b, m_pad // bt),
        in_specs=[
            x_spec_s,
            pl.BlockSpec((d, bs), lambda js, jb, jt: (0, js)),
            pl.BlockSpec((1, bs, d), lambda js, jb, jt: (jb, js, 0)),
            x_spec_s, tstat_s, tstat_s, tstat_s,
        ],
        out_specs=(
            pl.BlockSpec((1, bs, d), lambda js, jb, jt: (jb, js, 0)),
            pl.BlockSpec((d, bs), lambda js, jb, jt: (0, js)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, s_pad, d), ys.dtype),
            jax.ShapeDtypeStruct((d, s_pad), phi_n.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((bs, d), dt), pltpu.VMEM((d, bs), dt)],
        interpret=cfg.resolve_interpret(),
    )(*args)
    return dx[:, :m], dphi[:, :s], dys[:, :s]


# ---------------------------------------------------------------------------
# single-sequence back-compat wrappers (2D in / 2D out)
# ---------------------------------------------------------------------------


def _cfg_2d(bt, bs, interpret):
    return KernelConfig(block_tokens=bt, block_slots=bs, interpret=interpret)


def dispatch_pallas(x, phi_n, *, bt: int = 128, bs: int = 128,
                    interpret=None):
    """x: (m, d); phi_n: (d, S) pre-normalized. Returns slots (S, d)."""
    slots, _, _ = routing_fwd_pallas(x[None], phi_n, _cfg_2d(bt, bs,
                                                             interpret))
    return slots[0]


def combine_pallas(x, phi_n, ys, *, bt: int = 128, bs: int = 128,
                   interpret=None):
    """x: (m, d); phi_n: (d, S); ys: (S, d) expert outputs -> y (m, d)."""
    y, _ = combine_online_pallas(x[None], phi_n, ys[None],
                                 _cfg_2d(bt, bs, interpret))
    return y[0]
