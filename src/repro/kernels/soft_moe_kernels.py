"""Fused Soft-MoE dispatch/combine Pallas TPU kernels.

Why a kernel: the jnp path materializes the (m × S) logits in HBM *twice*
(once per softmax direction) plus the two weight tensors — at B/16 scale
(m=4096 tokens, S=4096 slots) that is 4 × 64MB of HBM traffic per layer
per sequence that never needs to exist. Both kernels below stream over the
contraction dimension with an online softmax (the flash-attention
rescaling trick applied to the paper's two softmax directions) and keep
only (block × d) tiles resident in VMEM:

  * dispatch: for each slot block, stream token blocks; online-softmax
    over TOKENS (the D direction) while accumulating the slot mix
    X~ = D^T X in the same pass. Logits never touch HBM.
  * combine: for each token block, stream slot blocks; online-softmax
    over SLOTS (the C direction) while accumulating Y = C Ys.

Tiling: d stays whole inside a block (the dot needs full rows); token and
slot tiles default to 128 — minor dims are multiples of 128 for MXU
alignment. VMEM at d=8192, bt=bs=128, f32 accumulators:
x-tile 4MB + phi-tile 4MB + acc 4MB + O(128) vectors ≈ 12MB < 16MB/core.

Phi arrives pre-normalized (scale * l2norm(phi) is O(d·S), done once
outside); X is l2-normalized inside the kernel (it is re-read every pass —
normalizing outside would double-read X from HBM).

Validated in interpret mode against ref.py (CPU has no MXU; TPU is the
target). Backward = custom_vjp with the ref-math VJP (kernels are
forward-optimized; the bwd einsums are already MXU-friendly XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _l2n(x, eps=1e-6):
    norm = jnp.sqrt(jnp.sum(jnp.square(x), axis=1, keepdims=True))
    return x * (1.0 / (norm + eps))


# ---------------------------------------------------------------------------
# dispatch: slots = D^T X, D = softmax over tokens
# ---------------------------------------------------------------------------


def _dispatch_kernel(x_ref, phi_ref, out_ref, acc, mx, den, *, m_valid, bt):
    jt = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(jt == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, _NEG)
        den[...] = jnp.zeros_like(den)

    x = x_ref[...].astype(jnp.float32)  # (bt, d) raw
    xn = _l2n(x)
    phi = phi_ref[...].astype(jnp.float32)  # (d, bs)
    logits = jax.lax.dot_general(
        xn, phi, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt, bs)
    # mask padded token rows (last block may be ragged)
    row = jt * bt + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    logits = jnp.where(row < m_valid, logits, _NEG)

    m_old = mx[...]
    m_new = jnp.maximum(m_old, logits.max(axis=0))  # (bs,)
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new[None, :])  # (bt, bs)
    den[...] = den[...] * corr + p.sum(axis=0)
    # acc: (bs, d) += p^T @ x   (raw x — the paper mixes unnormalized tokens)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    mx[...] = m_new

    @pl.when(jt == nt - 1)
    def _finish():
        out_ref[...] = (acc[...] / den[...][:, None]).astype(out_ref.dtype)


def dispatch_pallas(x, phi_n, *, bt: int = 128, bs: int = 128,
                    interpret: bool = True):
    """x: (m, d); phi_n: (d, S) pre-normalized. Returns slots (S, d)."""
    m, d = x.shape
    s = phi_n.shape[1]
    m_pad = pl.cdiv(m, bt) * bt
    s_pad = pl.cdiv(s, bs) * bs
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    if s_pad != s:
        phi_n = jnp.pad(phi_n, ((0, 0), (0, s_pad - s)))
    grid = (s_pad // bs, m_pad // bt)
    out = pl.pallas_call(
        functools.partial(_dispatch_kernel, m_valid=m, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda js, jt: (jt, 0)),
            pl.BlockSpec((d, bs), lambda js, jt: (0, js)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda js, jt: (js, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bs, d), jnp.float32),  # acc: slot mix
            pltpu.VMEM((bs,), jnp.float32),  # running max
            pltpu.VMEM((bs,), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(x, phi_n)
    return out[:s]


# ---------------------------------------------------------------------------
# combine: y = C Ys, C = softmax over slots
# ---------------------------------------------------------------------------


def _combine_kernel(x_ref, phi_ref, ys_ref, out_ref, acc, mx, den,
                    *, s_valid, bs):
    js = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(js == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, _NEG)
        den[...] = jnp.zeros_like(den)

    xn = _l2n(x_ref[...].astype(jnp.float32))  # (bt, d)
    phi = phi_ref[...].astype(jnp.float32)  # (d, bs)
    ys = ys_ref[...].astype(jnp.float32)  # (bs, d)
    logits = jax.lax.dot_general(
        xn, phi, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt, bs)
    col = js * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < s_valid, logits, _NEG)

    m_old = mx[...]
    m_new = jnp.maximum(m_old, logits.max(axis=1))  # (bt,)
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new[:, None])
    den[...] = den[...] * corr + p.sum(axis=1)
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p, ys, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    mx[...] = m_new

    @pl.when(js == ns - 1)
    def _finish():
        out_ref[...] = (acc[...] / den[...][:, None]).astype(out_ref.dtype)


def combine_pallas(x, phi_n, ys, *, bt: int = 128, bs: int = 128,
                   interpret: bool = True):
    """x: (m, d); phi_n: (d, S); ys: (S, d) expert outputs -> y (m, d)."""
    m, d = x.shape
    s = phi_n.shape[1]
    m_pad = pl.cdiv(m, bt) * bt
    s_pad = pl.cdiv(s, bs) * bs
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    if s_pad != s:
        phi_n = jnp.pad(phi_n, ((0, 0), (0, s_pad - s)))
        ys = jnp.pad(ys, ((0, s_pad - s), (0, 0)))
    grid = (m_pad // bt, s_pad // bs)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, s_valid=s, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda jt, js: (jt, 0)),
            pl.BlockSpec((d, bs), lambda jt, js: (0, js)),
            pl.BlockSpec((bs, d), lambda jt, js: (js, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda jt, js: (jt, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, d), jnp.float32),  # acc: combined output
            pltpu.VMEM((bt,), jnp.float32),  # running max
            pltpu.VMEM((bt,), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(x, phi_n, ys)
    return out[:m]
