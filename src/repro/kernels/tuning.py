"""Kernel-config subsystem: block sizes, accumulation dtype, interpret policy.

Replaces the two hardcoded policies the seed kernels shipped with:

  * ``bt = bs = 128`` baked into every ``pallas_call`` — now a
    ``KernelConfig`` that callers derive from ``MoEConfig`` (or autotune).
  * the import-time ``INTERPRET = jax.default_backend() != "tpu"`` global —
    backend is now evaluated **lazily per call** (``resolve_interpret``), so
    selecting a backend after import is never silently stale and tests can
    force either mode per call.

Block-size guidance (see kernels/README.md for the full table): the d axis
stays whole inside every tile, so VMEM pressure scales linearly with
``block_tokens + block_slots``.  128 is the MXU-aligned sweet spot for
d ≤ 8192; drop to 64 beyond that, and shrink ``block_slots`` first (the phi
tile is re-read per token block, so a smaller slot tile costs less refetch
traffic than a smaller token tile).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


def backend_is_tpu() -> bool:
    """Evaluated at call time, never at import time."""
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelConfig:
    """Per-call policy for the Soft-MoE Pallas kernels.

    ``interpret=None`` means "decide from the backend at call time" — the
    lazily-evaluated replacement for the old module global.
    """

    block_tokens: int = 128
    block_slots: int = 128
    acc_dtype: str = "float32"  # accumulator / softmax-stat dtype
    interpret: Optional[bool] = None
    # Paged decode-attention kernel: KV rows streamed per grid step.
    # 0 = one whole pool block per step (``paged_config`` subdivides pool
    # blocks larger than 128 rows so the VMEM tile stays bounded).
    paged_block_kv: int = 0

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return not backend_is_tpu()

    def acc(self):
        return jnp.dtype(self.acc_dtype)

    def replace(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)


def default_config(m: int, d: int, s: int,
                   base: Optional[KernelConfig] = None) -> KernelConfig:
    """Heuristic block sizes for a (tokens, d_model, slots) problem.

    Derived from the VMEM budget in kernels/README.md: tiles are
    (block, d) so at large d the block must shrink to keep
    x-tile + phi-tile + acc + dx/dphi accumulators under ~12 MB/core.
    Tiny problem axes clamp down so the pad waste stays bounded.
    """
    cfg = base or KernelConfig()
    bt, bs = cfg.block_tokens, cfg.block_slots
    if d > 8192:
        bt, bs = min(bt, 64), min(bs, 64)
    elif d > 4096:
        bs = min(bs, 64)
    # Don't tile far past the actual extent (pad waste); keep lane alignment.
    bt = max(8, min(bt, _round_up(m, 8)))
    bs = max(8, min(bs, _round_up(s, 8)))
    return cfg.replace(block_tokens=bt, block_slots=bs)


def config_from_moe(moe_cfg, m: int, d: int,
                    interpret: Optional[bool] = None) -> KernelConfig:
    """Build a KernelConfig from MoEConfig fields (0 = auto-heuristic)."""
    s = moe_cfg.total_slots()
    base = KernelConfig(
        acc_dtype=getattr(moe_cfg, "kernel_acc_dtype", "float32"),
        interpret=interpret,
    )
    cfg = default_config(m, d, s, base)
    bt = getattr(moe_cfg, "kernel_block_tokens", 0)
    bs = getattr(moe_cfg, "kernel_block_slots", 0)
    if bt:
        cfg = cfg.replace(block_tokens=bt)
    if bs:
        cfg = cfg.replace(block_slots=bs)
    return cfg


def paged_config(block_size: int, base: Optional[KernelConfig] = None,
                 interpret: Optional[bool] = None) -> KernelConfig:
    """Tile policy for the paged decode-attention kernel
    (kernels/paged_attention_kernels.py).

    One grid step streams ``paged_block_kv`` KV rows of one physical pool
    block into VMEM. Small pool blocks (the serving default, 16 tokens)
    stream whole; blocks beyond 128 rows are subdivided into the LARGEST
    divisor <= 128 (every block size has one — worst case 1) so the
    resident tile stays inside the VMEM budget whatever ``--block-size``
    the operator picks. The lazy ``interpret`` policy is inherited
    unchanged — CPU CI runs the kernel interpreted per call, never via
    an import-time global.
    """
    cfg = base or KernelConfig(interpret=interpret)
    bkv = cfg.paged_block_kv
    if not bkv:
        bkv = block_size
        if block_size > 128:
            bkv = next(c for c in range(128, 0, -1) if block_size % c == 0)
    assert block_size % bkv == 0, (
        f"paged_block_kv {bkv} must divide pool block_size {block_size}"
    )
    return cfg.replace(paged_block_kv=bkv)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# -- autotune sweep hook -----------------------------------------------------

DEFAULT_SWEEP: Sequence[tuple] = (
    (64, 64), (64, 128), (128, 64), (128, 128), (128, 256), (256, 128),
)


def autotune(build_fn: Callable[[KernelConfig], Callable[[], jax.Array]],
             base: Optional[KernelConfig] = None,
             sweep: Sequence[tuple] = DEFAULT_SWEEP,
             iters: int = 3) -> KernelConfig:
    """Time ``build_fn(cfg)()`` for each (block_tokens, block_slots) in the
    sweep and return the fastest config.  ``build_fn`` returns a nullary
    thunk (typically a jitted closure over real operands) so compile time
    is excluded via a warmup call.  Candidates that fail to trace/compile
    (e.g. VMEM overflow at large d) are skipped rather than fatal.
    """
    import time

    base = base or KernelConfig()
    best, best_t = base, float("inf")
    for bt, bs in sweep:
        cfg = base.replace(block_tokens=bt, block_slots=bs)
        try:
            fn = build_fn(cfg)
            jax.block_until_ready(fn())  # warmup/compile
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn())
            dt = (time.perf_counter() - t0) / iters
        except Exception:  # noqa: BLE001 — skip invalid tilings
            continue
        if dt < best_t:
            best, best_t = cfg, dt
    return best
