"""Pure-jnp oracle for the Soft-MoE kernels — the paper's Algorithm 1 + 2,
verbatim semantics, single sequence (batch handled by vmap in ops.py).

This is the reference the Pallas kernels are allclose-checked against, and
also the backward-pass implementation for the custom_vjp wrappers.

The single-sequence signature here is itself the per-sequence routing
invariant, stated as an API: dispatch normalizes over THIS sequence's m
tokens, combine over THIS sequence's S slots, and a batch is nothing but
an independent vmap of this oracle per row. Any batched implementation
(the fused Pallas kernels, the jnp einsum path in core/soft_moe.py) must
therefore agree row-for-row with this function applied to each row alone
— which is exactly what batch-invariant serving requires, and what
tests/test_kernels.py's row-independence checks assert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_normalize(x, axis, eps: float = 1e-6):
    norm = jnp.sqrt(jnp.square(x).sum(axis=axis, keepdims=True))
    return x * jnp.reciprocal(norm + eps)


def normalized_phi(phi, scale):
    """scale * l2norm(Phi) over d (axis 0). phi: (d, S)."""
    return scale * l2_normalize(phi, axis=0)


def logits_ref(x, phi_n):
    """x: (m, d) raw tokens; phi_n: (d, S) pre-normalized slot params."""
    xn = l2_normalize(x.astype(jnp.float32), axis=1)
    return xn @ phi_n.astype(jnp.float32)  # (m, S)


def dispatch_ref(x, phi_n):
    """Returns slots (S, d): X~ = D^T X with D = softmax over tokens."""
    logits = logits_ref(x, phi_n)
    d_w = jax.nn.softmax(logits, axis=0)  # per-slot over tokens
    return (d_w.T @ x.astype(jnp.float32)).astype(x.dtype)


def combine_ref(x, phi_n, ys):
    """Returns y (m, d): Y = C Ys with C = softmax over slots.
    ys: (S, d) expert outputs."""
    logits = logits_ref(x, phi_n)
    c_w = jax.nn.softmax(logits, axis=1)  # per-token over slots
    return (c_w @ ys.astype(jnp.float32)).astype(x.dtype)


def soft_moe_ref(x, phi, scale, expert_fn):
    """Full layer oracle (paper Algorithm 1 with Algorithm 2 norm)."""
    phi_n = normalized_phi(phi, scale)
    slots = dispatch_ref(x, phi_n)
    ys = expert_fn(slots)
    return combine_ref(x, phi_n, ys)
