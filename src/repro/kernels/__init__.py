"""Pallas TPU kernels for the Soft-MoE hot path (dispatch/combine, fused
forward AND flash-style backward, with pure-jnp oracles in ref.py — see
soft_moe_kernels.py) and for paged decode attention over the serving
block pool (paged_attention_kernels.py); tuning.py holds block-size /
interpret policy for all of them."""
from . import ops, ref, tuning  # noqa: F401
from .paged_attention_kernels import paged_decode_attend  # noqa: F401
from .soft_moe_kernels import (  # noqa: F401
    combine_apply_pallas,
    combine_bwd_pallas,
    combine_online_pallas,
    combine_pallas,
    dispatch_bwd_pallas,
    dispatch_pallas,
    routing_fwd_pallas,
    routing_health_pallas,
)
from .tuning import (  # noqa: F401
    KernelConfig,
    autotune,
    config_from_moe,
    default_config,
    paged_config,
)
