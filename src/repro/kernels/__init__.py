"""Pallas TPU kernels for the Soft-MoE hot path (dispatch/combine) with
pure-jnp oracles in ref.py; see soft_moe_kernels.py for the tiling story."""
from . import ops, ref  # noqa: F401
from .soft_moe_kernels import combine_pallas, dispatch_pallas  # noqa: F401
