"""Model zoo: decoder-only LM, encoder-decoder, ViT — plus a uniform
``build_model(cfg)`` entry point returning (init, loss, apply) fns."""
from __future__ import annotations

from .encdec import (  # noqa: F401
    encdec_apply,
    encdec_init,
    encdec_loss,
    init_encdec_cache,
)
from .lm import init_cache, lm_apply, lm_init, lm_loss, segment_plan  # noqa: F401
from .vit import vit_apply, vit_init, vit_loss  # noqa: F401


def build_model(cfg):
    """Returns (init_fn(rng), loss_fn(params, batch), apply_fn)."""
    if cfg.family == "vit":
        return (
            lambda rng: vit_init(rng, cfg),
            lambda p, b: vit_loss(p, cfg, b),
            lambda p, b, **kw: vit_apply(p, cfg, b["patches"], **kw),
        )
    if cfg.encoder_layers > 0:
        return (
            lambda rng: encdec_init(rng, cfg),
            lambda p, b: encdec_loss(p, cfg, b),
            lambda p, b, **kw: encdec_apply(
                p, cfg, b["tokens"], b.get("embeds"), **kw
            ),
        )
    return (
        lambda rng: lm_init(rng, cfg),
        lambda p, b: lm_loss(p, cfg, b),
        lambda p, b, **kw: lm_apply(
            p, cfg, b["tokens"], embeds=b.get("embeds"), **kw
        ),
    )
