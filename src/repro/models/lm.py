"""Decoder-only LM covering the dense / ssm / hybrid / moe / vlm families.

Training lowers one scan-over-layers per homogeneous *segment* (contiguous
layers with the same block structure — e.g. deepseek = [1 dense layer] +
[26 MoE layers]) with remat, MaxText-style: HLO size and compile time stay
bounded for 80-layer models. Serving (prefill/decode) unrolls a python loop
over layers so per-layer caches may be heterogeneous (ring buffers for
sliding-window layers, full-length for global layers, SSM states).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import moe_apply, moe_init, resolve_moe_cfg
from ..distributed.api import constrain
from ..layers.attention import attention_apply, attention_init, init_kv_cache
from ..layers.common import lecun_init, norm_apply, norm_init, split_rngs, stack_pytrees
from ..layers.embedding import embed, embedding_init, unembed
from ..layers.mlp import mlp_apply, mlp_init
from ..layers.ssm import init_ssm_cache, ssm_apply, ssm_init


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


def segment_plan(cfg, n_layers: Optional[int] = None) -> List[Tuple[int, int, bool]]:
    """Contiguous runs of (start, count, is_moe) with identical structure."""
    n = n_layers if n_layers is not None else cfg.num_layers
    moe_idx = set(cfg.moe_layer_indices())
    segs: List[Tuple[int, int, bool]] = []
    for i in range(n):
        is_moe = i in moe_idx
        if segs and segs[-1][2] == is_moe:
            start, count, _ = segs[-1]
            segs[-1] = (start, count + 1, is_moe)
        else:
            segs.append((i, 1, is_moe))
    return segs


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------


def block_init(rng, cfg, is_moe: bool):
    rs = split_rngs(rng, 4)
    d = cfg.d_model
    p = {"norm1": norm_init(cfg, d)}
    if cfg.has_attention():
        p["attn"] = attention_init(rs[0], cfg)
    if cfg.has_ssm():
        p["ssm"] = ssm_init(rs[1], cfg)
    if is_moe:
        p["norm2"] = norm_init(cfg, d)
        p["moe"] = moe_init(rs[2], d, resolve_moe_cfg(cfg.moe, cfg.d_ff),
                            cfg.mlp_style)
    elif cfg.d_ff > 0:
        p["norm2"] = norm_init(cfg, d)
        p["mlp"] = mlp_init(rs[3], d, cfg.d_ff, cfg.mlp_style)
    return p


def block_apply(params, cfg, x, *, is_moe: bool, is_global=True,
                positions=None, cache=None, mode: str = "train",
                use_kernel: bool = False, block_tables=None,
                paged_kernel: bool = False, telemetry: bool = False):
    """Returns (y, new_cache, aux) — or (y, new_cache, aux, telem) when
    ``telemetry=True``. `is_global` may be a traced bool (scan over
    gemma3's 5-local:1-global pattern with shared weights).
    ``block_tables`` (B, blocks_per_row) switches attention caches to the
    paged block-pool layout (shared by every layer — all attention layers
    write the same positions); ``paged_kernel`` additionally routes paged
    single-token decode through the Pallas paged-attention kernel.

    ``telemetry`` is a static build flag: the extra return is a dict of
    ``stop_gradient``'d f32 scalars (attention-path absmax, residual RMS,
    and the MoE routing-health set) with a structure fixed by the arch —
    the block's output is bit-identical either way."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    xn = norm_apply(params["norm1"], cfg, x)
    mix = 0.0
    a_out = None
    if cfg.has_attention():
        a_out, a_cache = attention_apply(
            params["attn"], cfg, xn,
            layer_is_global=is_global, positions=positions,
            cache=None if cache is None else cache.get("attn"),
            mode=mode, block_tables=block_tables,
            paged_kernel=paged_kernel,
        )
        mix = mix + a_out
        if new_cache is not None:
            new_cache["attn"] = a_cache
    if cfg.has_ssm():
        s_out, s_cache = ssm_apply(
            params["ssm"], cfg, xn,
            cache=None if cache is None else cache.get("ssm"), mode=mode,
            positions=positions,
        )
        if cfg.hybrid_parallel and cfg.has_attention():
            mix = (mix + s_out) * 0.5  # Hymba: mean-fuse parallel heads
        else:
            mix = mix + s_out
        if new_cache is not None:
            new_cache["ssm"] = s_cache
    x = x + constrain(mix, "batch", "seq", None)

    moe_telem = None
    if "norm2" in params:
        xn = norm_apply(params["norm2"], cfg, x)
        if is_moe:
            m_out, metrics = moe_apply(
                params["moe"], resolve_moe_cfg(cfg.moe, cfg.d_ff), xn,
                cfg.act, use_kernel=use_kernel, telemetry=telemetry,
                mode=mode,
            )
            aux = aux + metrics["moe_aux_loss"]
            moe_telem = metrics.get("telemetry")
        else:
            m_out = mlp_apply(params["mlp"], xn, cfg.act)
        x = x + constrain(m_out, "batch", "seq", None)
    if not telemetry:
        return x, new_cache, aux
    sg = jax.lax.stop_gradient
    telem = {
        "residual_rms": sg(jnp.sqrt(
            jnp.mean(jnp.square(x.astype(jnp.float32))))),
    }
    if a_out is not None:
        telem["max_attn_out"] = sg(
            jnp.abs(a_out.astype(jnp.float32)).max())
    if moe_telem is not None:
        telem["moe"] = moe_telem
    return x, new_cache, aux, telem


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _layer_flags(cfg, start: int, count: int):
    a = cfg.attention
    if a is None:
        return jnp.ones((count,), bool)
    return jnp.array(
        [a.is_global_layer(start + j) for j in range(count)], bool
    )


def lm_init(rng, cfg):
    rs = split_rngs(rng, 4)
    params = {"embed": embedding_init(rs[0], cfg.vocab_size, cfg.d_model)}
    if cfg.frontend.kind != "none":
        params["frontend"] = {
            "w": lecun_init(
                rs[1], (cfg.frontend.embed_dim, cfg.d_model),
                fan_in=cfg.frontend.embed_dim,
            )
        }
    segs = segment_plan(cfg)
    seg_params = []
    for start, count, is_moe in segs:
        blocks = [
            block_init(jax.random.fold_in(rs[2], start + j), cfg, is_moe)
            for j in range(count)
        ]
        seg_params.append(stack_pytrees(blocks))
    params["segments"] = seg_params
    params["final_norm"] = norm_init(cfg, cfg.d_model)
    if not cfg.tie_embeddings and cfg.vocab_size:
        params["unembed"] = embedding_init(rs[3], cfg.vocab_size, cfg.d_model)
    return params


def _remat_policy(cfg):
    # "nothing": recompute everything inside the layer (min memory).
    # "dots": save matmul outputs with no batch dims (less recompute).
    name = getattr(cfg, "remat_policy", "nothing")
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _scan_segment(seg_params, cfg, x, flags, is_moe, use_kernel, positions,
                  telemetry=False):
    def body(carry, xs):
        p, is_global = xs
        out = block_apply(
            p, cfg, carry, is_moe=is_moe, is_global=is_global,
            positions=positions, cache=None, mode="train",
            use_kernel=use_kernel, telemetry=telemetry,
        )
        if telemetry:
            y, _, aux, telem = out
            return y, (aux, telem)
        y, _, aux = out
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=_remat_policy(cfg), prevent_cse=False
        )
    x, ys = jax.lax.scan(body, x, (seg_params, flags))
    if telemetry:
        auxs, telem = ys  # telem leaves stacked over the segment: (count,)
        return x, auxs.sum(), telem
    return x, ys.sum(), None


def _unrolled_segment(seg_params, cfg, x, start, count, is_moe, caches,
                      positions, mode, use_kernel, block_tables=None,
                      paged_kernel=False, telemetry=False):
    """Python loop (serving path / scan_layers=False): heterogeneous caches."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    telems = {}
    for j in range(count):
        p = jax.tree_util.tree_map(lambda a: a[j], seg_params)
        is_global = (
            cfg.attention.is_global_layer(start + j)
            if cfg.attention is not None
            else True
        )
        cache_j = caches[start + j] if caches is not None else None
        out = block_apply(
            p, cfg, x, is_moe=is_moe, is_global=is_global,
            positions=positions, cache=cache_j, mode=mode,
            use_kernel=use_kernel, block_tables=block_tables,
            paged_kernel=paged_kernel, telemetry=telemetry,
        )
        if telemetry:
            x, c, a, telems[start + j] = out
        else:
            x, c, a = out
        aux = aux + a
        new_caches.append(c)
    return x, aux, new_caches, telems


def lm_apply(params, cfg, tokens, *, embeds=None, positions=None,
             cache=None, mode: str = "train", use_kernel: bool = False,
             last_only: bool = False, block_tables=None,
             paged_kernel: bool = False, telemetry: bool = False):
    """tokens: (B, S) int32; embeds: (B, N, E) frontend stub (vlm);
    positions: (S,) shared or (B, S) per-row (continuous-batching decode —
    entries < 0 mark pad/inactive tokens that neither write nor read any
    cache). ``block_tables`` (B, blocks_per_row) makes every attention
    cache a paged block pool (serve/block_manager.py) addressed through
    the tables; ``paged_kernel`` streams paged single-token decode through
    the Pallas paged-attention kernel instead of gathering per-row KV
    views. Returns (logits, new_cache, aux) — plus a trailing ``telem``
    pytree when ``telemetry=True`` (a STATIC build flag, never traced:
    existing 3-tuple call sites are untouched). ``telem`` holds
    fixed-shape ``stop_gradient``'d stats: per-layer block/MoE health
    keyed by layer index (scan segments stack leaves to ``(count,)``)
    and per-row logit numerics probes. ``last_only`` unembeds
    only the final position — prefill needs one next-token distribution,
    not S×vocab logits (at qwen2-72b:prefill_32k the full-logit tensor is
    32×32768×152064 f32 ≈ 638GB global)."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dtype)
    if embeds is not None and "frontend" in params:
        fe = embeds.astype(dtype) @ params["frontend"]["w"].astype(dtype)
        x = jnp.concatenate([fe, x], axis=1)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    x = constrain(x, "batch", "seq", None)

    aux = jnp.zeros((), jnp.float32)
    layer_telem = {}
    segs = segment_plan(cfg)
    if cache is None and cfg.scan_layers and mode == "train":
        for seg_params, (start, count, is_moe) in zip(params["segments"], segs):
            flags = _layer_flags(cfg, start, count)
            x, a, t = _scan_segment(
                seg_params, cfg, x, flags, is_moe, use_kernel, positions,
                telemetry,
            )
            aux = aux + a
            if t is not None:
                layer_telem[start] = t  # leaves stacked (count,)
        new_cache = None
    else:
        new_cache = []
        for seg_params, (start, count, is_moe) in zip(params["segments"], segs):
            x, a, cs, ts = _unrolled_segment(
                seg_params, cfg, x, start, count, is_moe, cache,
                positions, mode, use_kernel, block_tables, paged_kernel,
                telemetry,
            )
            aux = aux + a
            new_cache.extend(cs)
            layer_telem.update(ts)
        if cache is None:
            new_cache = None

    if last_only:
        x = x[:, -1:]
    x = norm_apply(params["final_norm"], cfg, x)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x, cfg.logits_softcap)
    if not telemetry:
        return logits, new_cache, aux
    sg = jax.lax.stop_gradient
    lf = logits.astype(jnp.float32)
    p = jax.nn.softmax(lf, axis=-1)
    lse = jax.nn.logsumexp(lf, axis=-1)
    telem = {
        "layers": layer_telem,
        "logits": {
            # per-row reductions: (B,) — continuous batching mixes
            # unrelated requests in one tick, so rows stay separable
            "max_abs_logit": sg(jnp.abs(lf).max(axis=(1, 2))),
            "softmax_entropy": sg((lse - jnp.sum(p * lf, axis=-1)
                                   ).mean(axis=1)),
            "nonfinite_count": sg(jnp.sum(
                ~jnp.isfinite(lf), axis=(1, 2)).astype(jnp.float32)),
        },
    }
    return logits, new_cache, aux, telem


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-layer cache list (python list pytree — heterogeneous lengths).

    Every leaf has a leading `batch` dim, and attention caches carry a
    per-row (batch, length) `pos` array — rows advance independently, so
    the serving layer (serve/cache_pool.py) can admit/retire individual
    rows at any decode step (continuous batching)."""
    caches = []
    for i in range(cfg.num_layers):
        c = {}
        if cfg.has_attention():
            a = cfg.attention
            c["attn"] = init_kv_cache(
                cfg, batch, max_len, a.is_global_layer(i), dtype
            )
        if cfg.has_ssm():
            c["ssm"] = init_ssm_cache(cfg, batch, dtype)
        caches.append(c)
    return caches


def lm_loss(params, cfg, batch, use_kernel: bool = False,
            telemetry: bool = False):
    """Next-token cross-entropy. batch: {"tokens": (B,S) [, "embeds"]}

    ``telemetry=True`` (static flag) adds the ``lm_apply`` telemetry
    pytree under ``metrics["telemetry"]`` — loss value is unchanged."""
    tokens = batch["tokens"]
    out = lm_apply(
        params, cfg, tokens, embeds=batch.get("embeds"), mode="train",
        use_kernel=use_kernel, telemetry=telemetry,
    )
    telem = out[3] if telemetry else None
    logits, _, aux = out[:3]
    # frontend embeds prepend non-text positions; score text only
    n_prefix = logits.shape[1] - tokens.shape[1]
    logits = logits[:, n_prefix:]
    targets = tokens[:, 1:]
    nll = cross_entropy(logits[:, :-1], targets)
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        loss = (nll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    else:
        loss = nll.mean()
    metrics = {"loss": loss, "aux_loss": aux}
    if telem is not None:
        metrics["telemetry"] = telem
    return loss + aux, metrics


def cross_entropy(logits, targets):
    """Sharding-friendly CE: lse(logits) - logits[target]. Unlike
    take_along_axis over the (model-sharded) vocab axis — which forces an
    all-gather of the full logits (40GB/device at the 152k-vocab train_4k
    cell) — both terms reduce over the local vocab shard and psum per
    token. The target pick is a masked reduce (select fuses; an explicit
    one_hot would materialize a (B,S,V/16) f32 tensor)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(
        jnp.where(vocab_iota == targets[..., None], logits, 0.0), axis=-1
    )
    return lse - picked
