"""Vision Transformer — the paper's own backbone family (§3). Patch
embeddings come from the frontend stub (flattened patches projected
linearly); encoder blocks are non-causal; classification by mean-pool +
linear head (the v-moe/ViT "gap" head). Soft MoE / sparse MoE layers slot
into the second half of blocks per the config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.api import constrain
from ..layers.common import lecun_init, norm_apply, norm_init, split_rngs, stack_pytrees, truncated_normal
from .lm import block_apply, block_init, segment_plan


def vit_init(rng, cfg, num_classes: int = 1000):
    rs = split_rngs(rng, 5)
    d = cfg.d_model
    params = {
        "patch_proj": {
            "w": lecun_init(rs[0], (cfg.frontend.embed_dim, d),
                            fan_in=cfg.frontend.embed_dim),
            "b": jnp.zeros((d,)),
        },
        "pos_emb": truncated_normal(rs[1], (cfg.frontend.num_embeds, d), 0.02),
        "segments": [
            stack_pytrees(
                [
                    block_init(jax.random.fold_in(rs[2], start + j), cfg, is_moe)
                    for j in range(count)
                ]
            )
            for start, count, is_moe in segment_plan(cfg)
        ],
        "final_norm": norm_init(cfg, d),
        "head": {
            "w": jnp.zeros((d, num_classes)),
            "b": jnp.zeros((num_classes,)),
        },
    }
    return params


def vit_apply(params, cfg, patches, use_kernel: bool = False):
    """patches: (B, num_patches, patch_dim) -> (B, num_classes) logits."""
    dt = jnp.dtype(cfg.dtype)
    x = patches.astype(dt) @ params["patch_proj"]["w"].astype(dt)
    x = x + params["patch_proj"]["b"].astype(dt)
    x = x + params["pos_emb"].astype(dt)
    x = constrain(x, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    for seg_params, (start, count, is_moe) in zip(
        params["segments"], segment_plan(cfg)
    ):
        def body(carry, p, _is_moe=is_moe):
            y, _, a = block_apply(
                p, cfg, carry, is_moe=_is_moe, mode="train",
                use_kernel=use_kernel,
            )
            return y, a

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxs = jax.lax.scan(body, x, seg_params)
        aux = aux + auxs.sum()
    x = norm_apply(params["final_norm"], cfg, x)
    pooled = x.mean(axis=1).astype(jnp.float32)
    logits = pooled @ params["head"]["w"].astype(jnp.float32) + params["head"]["b"]
    return logits, aux


def vit_loss(params, cfg, batch, use_kernel: bool = False):
    logits, aux = vit_apply(params, cfg, batch["patches"],
                            use_kernel=use_kernel)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll + aux, {"loss": nll, "aux_loss": aux, "accuracy": acc}


def vit_features(params, cfg, patches, use_kernel: bool = False):
    """Mean-pooled pre-head features (for the LIT-style contrastive example)."""
    dt = jnp.dtype(cfg.dtype)
    x = patches.astype(dt) @ params["patch_proj"]["w"].astype(dt)
    x = x + params["patch_proj"]["b"].astype(dt) + params["pos_emb"].astype(dt)
    for seg_params, (start, count, is_moe) in zip(
        params["segments"], segment_plan(cfg)
    ):
        def body(carry, p, _is_moe=is_moe):
            y, _, a = block_apply(p, cfg, carry, is_moe=_is_moe, mode="train",
                                  use_kernel=use_kernel)
            return y, a

        x, _ = jax.lax.scan(body, x, seg_params)
    x = norm_apply(params["final_norm"], cfg, x)
    return x.mean(axis=1)
