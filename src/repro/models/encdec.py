"""Encoder-decoder backbone (seamless-m4t): audio frontend STUB feeds
precomputed frame embeddings to the encoder; the decoder self-attends
causally and cross-attends to the encoder output.

The encoder is non-causal, so Soft MoE is natively applicable there
(paper's own setting); the decoder carries the causality caveat
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import moe_apply, moe_init
from ..distributed.api import constrain
from ..layers.attention import (
    _attend,
    _attend_chunked,
    _CHUNKED_THRESHOLD,
    attention_apply,
    attention_init,
    gqa_init,
    init_kv_cache,
    make_mask,
)
from ..layers.common import lecun_init, norm_apply, norm_init, split_rngs, stack_pytrees
from ..layers.embedding import embed, embedding_init, unembed
from ..layers.mlp import mlp_apply, mlp_init
from ..layers.rotary import apply_rope
from .lm import block_init, segment_plan


# --- cross attention --------------------------------------------------------


def cross_attn_init(rng, cfg):
    return gqa_init(rng, cfg)


def cross_attn_apply(params, cfg, x, enc_kv, enc_mask=None):
    """x: (B,S,d) decoder side; enc_kv: {"k","v"} precomputed (B,T,G,hd)."""
    a = cfg.attention
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k, v = enc_kv["k"], enc_kv["v"]
    if k.shape[1] * x.shape[1] > _CHUNKED_THRESHOLD:
        kpos = jnp.arange(k.shape[1])
        out = _attend_chunked(q, k, v, jnp.zeros((x.shape[1],), jnp.int32),
                              kpos * 0, False, None)
    else:
        out = _attend(q, k, v, None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def cross_kv(params, cfg, enc_out):
    k = jnp.einsum("btd,dgk->btgk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dgk->btgk", enc_out, params["wv"].astype(enc_out.dtype))
    return {"k": k, "v": v}


# --- model ------------------------------------------------------------------


def _enc_cfg(cfg):
    return dataclasses.replace(cfg, causal=False, num_layers=cfg.encoder_layers)


def encdec_init(rng, cfg):
    rs = split_rngs(rng, 6)
    enc_cfg = _enc_cfg(cfg)
    moe_idx = set(cfg.moe_layer_indices())
    params = {
        "embed": embedding_init(rs[0], cfg.vocab_size, cfg.d_model),
        "frontend": {
            "w": lecun_init(
                rs[1], (cfg.frontend.embed_dim, cfg.d_model),
                fan_in=cfg.frontend.embed_dim,
            )
        },
        "enc_segments": [
            stack_pytrees(
                [
                    block_init(
                        jax.random.fold_in(rs[2], start + j), enc_cfg, is_moe
                    )
                    for j in range(count)
                ]
            )
            for start, count, is_moe in segment_plan(enc_cfg)
        ],
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_blocks": [
            {
                "self": block_init(jax.random.fold_in(rs[3], i), cfg,
                                   i in moe_idx),
                "cross_norm": norm_init(cfg, cfg.d_model),
                "cross": cross_attn_init(jax.random.fold_in(rs[4], i), cfg),
            }
            for i in range(cfg.num_layers)
        ],
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embedding_init(rs[5], cfg.vocab_size, cfg.d_model)
    return params


def encode(params, cfg, frames):
    """frames: (B, T, E) precomputed frontend embeddings (stub)."""
    from .lm import block_apply  # local import to avoid cycle

    enc_cfg = _enc_cfg(cfg)
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend"]["w"].astype(
        jnp.dtype(cfg.dtype)
    )
    x = constrain(x, "batch", "seq", None)
    aux_total = jnp.zeros((), jnp.float32)
    for seg_params, (start, count, is_moe) in zip(
        params["enc_segments"], segment_plan(enc_cfg)
    ):
        def body(carry, p, _is_moe=is_moe):
            y, _, aux = block_apply(
                p, enc_cfg, carry, is_moe=_is_moe, mode="train"
            )
            return y, aux

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxs = jax.lax.scan(body, x, seg_params)
        aux_total = aux_total + auxs.sum()
    return norm_apply(params["enc_norm"], cfg, x), aux_total


def decode_step(params, cfg, tokens, enc_out, *, positions=None,
                cache=None, mode: str = "train", last_only: bool = False):
    """Decoder over tokens with cross-attention to enc_out."""
    from .lm import block_apply

    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    new_cache = [] if cache is not None else None
    moe_idx = set(cfg.moe_layer_indices())
    for i, blk in enumerate(params["dec_blocks"]):
        cache_i = cache[i] if cache is not None else None
        x, c, a = block_apply(
            blk["self"], cfg, x, is_moe=i in moe_idx, positions=positions,
            cache=None if cache_i is None else cache_i.get("self"), mode=mode,
        )
        aux = aux + a
        xn = norm_apply(blk["cross_norm"], cfg, x)
        if cache_i is not None and "cross_kv" in cache_i:
            kv = cache_i["cross_kv"]
        else:
            kv = cross_kv(blk["cross"], cfg, enc_out)
        x = x + cross_attn_apply(blk["cross"], cfg, xn, kv)
        if new_cache is not None:
            new_cache.append({"self": c, "cross_kv": kv})
    if last_only:
        x = x[:, -1:]
    x = norm_apply(params["final_norm"], cfg, x)
    table = params.get("unembed", params["embed"])
    return unembed(table, x, cfg.logits_softcap), new_cache, aux


def encdec_apply(params, cfg, tokens, frames, *, positions=None, cache=None,
                 enc_out=None, mode: str = "train"):
    """Full enc-dec forward. For decode mode, pass enc_out (+cache) from a
    prior prefill instead of frames."""
    aux = jnp.zeros((), jnp.float32)
    if enc_out is None:
        enc_out, enc_aux = encode(params, cfg, frames)
        aux = aux + enc_aux
    logits, new_cache, dec_aux = decode_step(
        params, cfg, tokens, enc_out, positions=positions, cache=cache,
        mode=mode,
    )
    return logits, (enc_out, new_cache), aux + dec_aux


def init_encdec_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return [
        {"self": {"attn": init_kv_cache(cfg, batch, max_len, True, dtype)}}
        for _ in range(cfg.num_layers)
    ]


def encdec_loss(params, cfg, batch):
    tokens = batch["tokens"]
    logits, _, aux = encdec_apply(params, cfg, tokens, batch["embeds"])
    targets = tokens[:, 1:]
    from .lm import cross_entropy

    nll = cross_entropy(logits[:, :-1], targets)
    loss = nll.mean()
    return loss + aux, {"loss": loss, "aux_loss": aux}
