"""Deterministic synthetic data pipelines.

JFT-4B / WebLI are proprietary; these streams reproduce the *shapes* and
give the models a learnable signal so the examples show real loss curves:

  * SyntheticLM — order-1 Markov token stream (random stochastic matrix
    with low entropy), so cross-entropy has a clear floor below ln(V).
  * SyntheticImages — random patch fields whose label is a (fixed random)
    linear readout of mean patch statistics: linearly separable, so
    accuracy rises fast — good for smoke-testing ViT/Soft-MoE training.

Determinism/restart: batch(step) is a pure function of (seed, step), so a
restarted job resumes the stream exactly — the data pipeline needs no
checkpoint state. Multi-host: each host takes its slice by (host_id,
num_hosts), matching the batch sharding over the (pod, data) axes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 4  # tokens reachable from each state
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # transition table cap
        self._v = v
        self._next = rng.integers(0, v, size=(v, self.branching))

    def batch(self, step: int):
        per_host = self.batch_size // self.num_hosts
        rng = jax.random.PRNGKey(
            (self.seed * 1_000_003 + step) * 131 + self.host_id
        )
        r_start, r_choice = jax.random.split(rng)
        starts = jax.random.randint(r_start, (per_host,), 0, self._v)
        choices = jax.random.randint(
            r_choice, (per_host, self.seq_len), 0, self.branching
        )
        table = jnp.asarray(self._next)

        def walk(s0, ch):
            def body(s, c):
                nxt = table[s, c]
                return nxt, nxt

            _, toks = jax.lax.scan(body, s0, ch)
            return toks

        tokens = jax.vmap(walk)(starts, choices)
        return {"tokens": tokens.astype(jnp.int32)}


@dataclass
class SyntheticImages:
    num_patches: int
    patch_dim: int
    batch_size: int
    num_classes: int = 1000
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._readout = rng.standard_normal((self.patch_dim, self.num_classes))

    def batch(self, step: int):
        per_host = self.batch_size // self.num_hosts
        rng = jax.random.PRNGKey(
            (self.seed * 999_983 + step) * 131 + self.host_id
        )
        patches = jax.random.normal(
            rng, (per_host, self.num_patches, self.patch_dim)
        )
        feats = patches.mean(axis=1)
        logits = feats @ jnp.asarray(self._readout, feats.dtype)
        labels = jnp.argmax(logits, axis=-1)
        return {"patches": patches, "labels": labels.astype(jnp.int32)}


@dataclass
class SyntheticSeq2Seq:
    """Frame-embeddings -> token stream (seamless-style stub)."""

    vocab_size: int
    seq_len: int
    num_frames: int
    frame_dim: int
    batch_size: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def batch(self, step: int):
        per_host = self.batch_size // self.num_hosts
        rng = jax.random.PRNGKey(
            (self.seed * 7_368_787 + step) * 131 + self.host_id
        )
        r_f, r_t = jax.random.split(rng)
        frames = jax.random.normal(
            r_f, (per_host, self.num_frames, self.frame_dim)
        )
        tokens = jax.random.randint(
            r_t, (per_host, self.seq_len), 0, self.vocab_size
        )
        return {"tokens": tokens.astype(jnp.int32), "embeds": frames}
