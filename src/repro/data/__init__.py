from .pipeline import SyntheticImages, SyntheticLM, SyntheticSeq2Seq  # noqa: F401
