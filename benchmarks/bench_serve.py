"""Serving benchmark: wave vs contiguous vs paged (Pallas kernel and
jnp-gather decode) under a Poisson trace, plus a shared-system-prompt
trace through the radix prefix cache and a paged-attention
kernel-vs-gather decode phase.

Replays one fixed trace of mixed-length requests (Poisson arrivals,
uniform prompt lengths and token budgets) through the engines and
reports throughput (generated tokens / makespan), per-request latency
(submit -> done) and TTFT (submit -> first token) percentiles, and peak
cache memory (peak LIVE-request block footprint for the paged engine vs
the fixed num_slots x max_len reservation). A second phase serves
requests sharing one system prompt with the prefix cache cold vs warm
and measures the TTFT reduction. A third phase saturates the decode
batch and compares the paged-attention kernel against the jnp row-view
gather: token-for-token greedy parity (asserted), decode tok/s, and the
modeled HBM bytes/step each path touches. On CPU the kernel runs in
Pallas interpret mode, so its wall-clock is an emulation artifact (the
PR 1 kernels' caveat applies verbatim) — the tok/s >= gather gate is
enforced only when the kernel actually compiles to hardware; the
traffic model and the parity/materialization proofs are backend-
independent.

  PYTHONPATH=src python benchmarks/bench_serve.py            # full trace
  PYTHONPATH=src python benchmarks/bench_serve.py --smoke    # CI-sized

A fourth phase runs self-drafting SPECULATIVE decoding (serve/
spec_decode.py) against the plain paged engine on the same decode-
saturated trace: greedy parity asserted, acceptance rate and decode
model-calls-per-token reported (< 1.0 gated off-smoke — speculation must
win arithmetically; the wall-clock gate arms only off-interpret).

A fifth phase drives a multi-tenant OVERLOAD trace (interactive
requests with tight TTFT deadlines + batch requests, bursty arrivals
over budget) through the asyncio front end (serve/server.py): load-shed
rate, deadline-miss rate and queue-time percentiles are reported and
the block pool is asserted leak-free afterwards — the CI chaos-smoke
job greps these counters.

A sixth phase exercises the MODEL-INTERIOR telemetry (serve/
telemetry.py): token parity with the side outputs compiled in
(asserted), roofline-vs-measured program efficiency attribution, and
the batch-variance probe — target-row routing-stat divergence solo vs
co-batched, finite on a group-routed BPR sparse-MoE reference and ~0
on row-independent routing (the ROADMAP batch-invariant-serving
acceptance instrument).

Emits `name,us_per_call,derived` rows (benchmarks/common.py contract),
a human-readable summary, AND machine-readable ``BENCH_serve.json`` at
the repo root. The JSON keeps the latest-run summary at the top level
and APPENDS a compact per-run record (git rev, date, tok/s, p50/p99,
spec acceptance) to a ``history`` list — the cross-PR perf trajectory
survives reruns instead of being overwritten wholesale. Smoke runs
write only the gitignored ``BENCH_serve.smoke.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.models import lm_init  # noqa: E402
from repro.serve import (  # noqa: E402
    Request,
    SamplingParams,
    ServeEngine,
    SpecConfig,
    WaveEngine,
    parse_prometheus,
)

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _git_rev() -> str:
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def make_trace(n_requests: int, rate: float, seed: int = 0):
    """(arrival_time, prompt, max_new, sampling) tuples; Poisson arrivals
    at `rate` req/s, prompt len U[4,24], budget U[4,32], a mix of greedy
    and temperature/top-k/top-p rows."""
    rng = np.random.RandomState(seed)
    t = 0.0
    trace = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.randint(4, 25))
        prompt = [int(x) for x in rng.randint(1, 200, size=plen)]
        max_new = int(rng.randint(4, 33))
        if i % 3 == 0:
            sp = SamplingParams()  # greedy
        elif i % 3 == 1:
            sp = SamplingParams(temperature=0.8, top_k=40, seed=i)
        else:
            sp = SamplingParams(temperature=1.0, top_p=0.9, seed=i)
        trace.append((t, prompt, max_new, sp))
    return trace


def replay(engine, trace, tick):
    """Drive `engine` against wall-clock arrivals; returns (makespan_s,
    requests). `tick(engine)` advances the engine one step when work is
    available."""
    reqs = [
        Request(prompt=p, max_new_tokens=m, sampling=sp)
        for (_, p, m, sp) in trace
    ]
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            engine.submit(reqs[i])
            i += 1
        if all(r.done for r in reqs):
            break
        if not tick(engine):
            if i < len(trace):  # idle: wait for the next arrival
                time.sleep(min(0.001, trace[i][0] - now))
    return time.perf_counter() - t0, reqs


def continuous_tick(eng):
    if eng.sched.pending():
        eng.step()
        return True
    return False


def wave_tick(eng):
    if eng.queue:
        eng.run()  # drains currently-queued waves; late arrivals wait
        return True
    return False


def summarize(label, makespan, reqs, decode_steps, peak_bytes):
    total_tokens = sum(len(r.out) for r in reqs)
    # Stop-cause histogram: cache_ceiling entries are TRUNCATIONS the
    # operator should see, not normal completions.
    reasons = {}
    for r in reqs:
        key = r.finish_reason or "unknown"
        reasons[key] = reasons.get(key, 0) + 1
    lat = np.array([r.t_done - r.t_submit for r in reqs])
    ttft = np.array([r.t_first_token - r.t_submit for r in reqs])
    tps = total_tokens / makespan
    p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
    t50, t99 = np.percentile(ttft, 50), np.percentile(ttft, 99)
    reason_s = " ".join(f"{k}:{v}" for k, v in sorted(reasons.items()))
    print(f"{label:12s} {total_tokens:5d} tok in {makespan:6.2f}s "
          f"-> {tps:7.1f} tok/s | latency p50 {p50*1e3:7.1f}ms "
          f"p99 {p99*1e3:7.1f}ms | ttft p50 {t50*1e3:6.1f}ms | "
          f"{decode_steps} decode calls | peak cache "
          f"{peak_bytes/1e6:.2f}MB | finish {reason_s}")
    emit(f"serve_{label}_tok_s", 1e6 / max(tps, 1e-9), f"{tps:.1f} tok/s")
    emit(f"serve_{label}_p50", p50 * 1e6, "per-request latency")
    emit(f"serve_{label}_p99", p99 * 1e6, "per-request latency")
    emit(f"serve_{label}_ttft_p50", t50 * 1e6, "submit->first token")
    return {
        "tok_s": tps,
        "total_tokens": total_tokens,
        "makespan_s": makespan,
        "latency_p50_s": float(p50),
        "latency_p99_s": float(p99),
        "ttft_p50_s": float(t50),
        "ttft_p99_s": float(t99),
        "decode_calls": int(decode_steps),
        "peak_cache_bytes": int(peak_bytes),
        "finish_reasons": reasons,
    }


def modeled_decode_hbm_bytes(cfg, batch, blocks_per_row, block_size,
                             kernel: bool) -> int:
    """Attention-cache HBM traffic per batched decode step (the quantity
    the paged-attention kernel exists for). The kernel streams each pool
    tile into VMEM once; the gather path touches the same bytes three
    times — gather-read the pool, write the (B, L, ...) row view, read it
    back in the attend. Weights/activations are identical either way and
    excluded."""
    a = cfg.attention
    if a is None:
        return 0
    kv_bytes = 2  # bf16 pool
    if a.kind == "mla":
        per_tok = (a.kv_lora_rank + a.qk_rope_head_dim) * kv_bytes + 4
    else:
        per_tok = 2 * a.num_kv_heads * a.head_dim * kv_bytes + 4  # k+v+pos
    stream = batch * blocks_per_row * block_size * per_tok
    return cfg.num_layers * (stream if kernel else 3 * stream)


def bench_paged_kernel(cfg, params, batch, max_len, block_size,
                       budget: int):
    """Paged-attention kernel vs jnp gather, decode-saturated: fill every
    slot with a greedy request and drain. Token streams must be
    IDENTICAL (asserted — the gather path is the kernel's oracle);
    reports decode tok/s per path and the modeled HBM bytes/step.
    Returns None on archs whose decode never takes the kernel (no
    attention, or MLA's absorbed latent decode) — comparing two gather
    engines there would report a fabricated saving."""
    from repro.kernels.tuning import backend_is_tpu

    if cfg.attention is None or cfg.attention.kind == "mla":
        print("paged-kernel  n/a (GQA decode only: no attention / MLA "
              "keeps the gather fallback)")
        return None
    streams, rates = {}, {}
    for label, uk in (("gather", False), ("kernel", True)):
        eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                          backend="paged", block_size=block_size,
                          use_kernel=uk)
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
        eng.run()  # compile warmup outside the timed window
        reqs = [Request(prompt=[(i + 1) * 7 % 200 + 1] * 8,
                        max_new_tokens=budget) for i in range(batch)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in reqs)
        streams[label] = [r.out for r in reqs]
        rates[label] = toks / dt
    assert streams["kernel"] == streams["gather"], (
        "paged-attention kernel diverged from the gather oracle"
    )
    blocks_per_row = -(-max_len // block_size)
    hbm_k = modeled_decode_hbm_bytes(cfg, batch, blocks_per_row,
                                     block_size, kernel=True)
    hbm_g = modeled_decode_hbm_bytes(cfg, batch, blocks_per_row,
                                     block_size, kernel=False)
    emulated = not backend_is_tpu()
    ratio = rates["kernel"] / max(rates["gather"], 1e-9)
    note = " [interpret-mode emulation artifact]" if emulated else ""
    print(f"paged-kernel  decode {rates['kernel']:7.1f} tok/s vs gather "
          f"{rates['gather']:7.1f} tok/s ({ratio:.2f}x{note}) | modeled "
          f"HBM {hbm_k/1e3:.1f}KB/step vs {hbm_g/1e3:.1f}KB "
          f"({hbm_g/max(hbm_k,1):.1f}x less traffic) | greedy parity OK")
    emit("serve_paged_kernel_decode_tok_s", 1e6 / max(rates["kernel"], 1e-9),
         f"{rates['kernel']:.1f} tok/s")
    emit("serve_paged_gather_decode_tok_s", 1e6 / max(rates["gather"], 1e-9),
         f"{rates['gather']:.1f} tok/s")
    emit("serve_paged_kernel_hbm_saving", hbm_g / max(hbm_k, 1) * 1e6,
         "modeled gather/kernel bytes per decode step")
    return {
        "decode_tok_s_kernel": rates["kernel"],
        "decode_tok_s_gather": rates["gather"],
        "kernel_over_gather_tok_s": float(ratio),
        "modeled_hbm_bytes_per_step_kernel": int(hbm_k),
        "modeled_hbm_bytes_per_step_gather": int(hbm_g),
        "modeled_hbm_traffic_saving": float(hbm_g / max(hbm_k, 1)),
        "greedy_parity": True,
        "emulated_interpret": emulated,
    }


def bench_spec_decode(cfg, params, batch, max_len, block_size,
                      budget: int, spec_k: int = 4):
    """Self-drafting speculative decoding vs the plain paged engine on a
    decode-saturated greedy trace (every slot busy, long budgets — the
    regime where per-step model calls dominate). Greedy parity is
    ASSERTED token-for-token: speculation must be lossless. Reports the
    draft acceptance rate and decode model-calls-per-token (< 1.0 means
    speculation wins arithmetically whatever the wall clock says); both
    engines decode through the jnp gather path so the CPU comparison is
    apples-to-apples (the verify step is S=k+1 and cannot use the
    single-query Pallas kernel — on hardware the plain baseline would
    run the kernel, which the tok/s gate accounts for)."""
    from repro.kernels.tuning import backend_is_tpu

    if cfg.attention is None or cfg.has_ssm():
        print("spec-decode   n/a (needs a rollbackable attention cache)")
        return None

    def mk_reqs():
        return [Request(prompt=[(i + 1) * 7 % 200 + 1] * 8,
                        max_new_tokens=budget) for i in range(batch)]

    streams, rates, calls = {}, {}, {}
    spec_eng = None
    for label in ("plain", "spec"):
        eng = ServeEngine(
            cfg, params, batch_size=batch, max_len=max_len,
            backend="paged", block_size=block_size, use_kernel=False,
            spec=SpecConfig(k=spec_k) if label == "spec" else None,
        )
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        eng.run()  # compile warmup outside the timed window
        eng.decode_steps = 0
        if eng._spec is not None:
            eng._spec.reset_stats()  # acceptance must carry only the trace
        warm_sizes = eng.jit_cache_sizes()
        reqs = mk_reqs()
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        assert eng.jit_cache_sizes() == warm_sizes, (
            f"{label} decode recompiled under churn"
        )
        toks = sum(len(r.out) for r in reqs)
        streams[label] = [r.out for r in reqs]
        rates[label] = toks / dt
        # PER-ROW model calls per generated token (the spec-decoding
        # literature's metric): a batched decode call advances every
        # row, so dividing by total batch tokens would credit plain
        # batching with "calls/token < 1" and the gate would be vacuous.
        calls[label] = eng.decode_steps / max(toks / batch, 1e-9)
        if label == "spec":
            spec_eng = eng
    assert streams["spec"] == streams["plain"], (
        "speculative decoding diverged from the greedy baseline"
    )
    stats = spec_eng.spec_stats()
    emulated = not backend_is_tpu()
    ratio = rates["spec"] / max(rates["plain"], 1e-9)
    print(f"spec-decode   k={spec_k} acceptance "
          f"{stats['acceptance_rate']:.2f} | decode calls/token "
          f"{calls['spec']:.2f} vs {calls['plain']:.2f} plain | "
          f"{rates['spec']:7.1f} tok/s vs {rates['plain']:7.1f} "
          f"({ratio:.2f}x) | greedy parity OK")
    emit("serve_spec_decode_tok_s", 1e6 / max(rates["spec"], 1e-9),
         f"{rates['spec']:.1f} tok/s")
    emit("serve_spec_acceptance", stats["acceptance_rate"] * 1e6,
         "accepted/drafted")
    emit("serve_spec_calls_per_token", calls["spec"] * 1e6,
         "decode model calls per generated token")
    return {
        "spec_k": spec_k,
        "acceptance_rate": float(stats["acceptance_rate"]),
        "drafted": int(stats["drafted"]),
        "accepted": int(stats["accepted"]),
        "decode_calls_per_token_spec": float(calls["spec"]),
        "decode_calls_per_token_plain": float(calls["plain"]),
        "decode_tok_s_spec": rates["spec"],
        "decode_tok_s_plain": rates["plain"],
        "spec_over_plain_tok_s": float(ratio),
        "greedy_parity": True,
        "emulated_interpret": emulated,
    }


def bench_prefix_cache(cfg, params, batch, max_len, n_warm: int):
    """Shared-system-prompt trace: one cold request populates the radix
    tree, `n_warm` same-prefix requests ride it. Requests run one at a
    time so TTFT measures prefill work, not queueing."""
    sys_prompt = list(np.random.RandomState(7).randint(1, 200, size=48))
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      backend="paged", block_size=16, prefill_chunk=16)
    if eng.backend.prefix is None:  # SSM/hybrid archs: no prefix sharing
        print("prefix-cache  n/a (recurrent SSM state is not "
              "block-addressable on this arch)")
        return None
    # compile warmup on a disjoint prompt (prefix cache stays cold for
    # the measured system prompt)
    eng.submit(Request(prompt=[201, 202, 203], max_new_tokens=2))
    eng.run()

    def one(suffix):
        r = Request(prompt=sys_prompt + suffix, max_new_tokens=4)
        eng.submit(r)
        eng.run()
        return r.t_first_token - r.t_submit

    cold_ttft = one([1, 2, 3])
    warm = [one([i + 10, i + 20]) for i in range(n_warm)]
    warm_ttft = float(np.median(warm))
    reduction = cold_ttft / max(warm_ttft, 1e-9)
    print(f"prefix-cache  cold TTFT {cold_ttft*1e3:6.1f}ms  warm "
          f"{warm_ttft*1e3:6.1f}ms  ({reduction:.1f}x, "
          f"{eng.backend.prefix.hits} block hits)")
    emit("serve_prefix_cold_ttft", cold_ttft * 1e6, "48-tok system prompt")
    emit("serve_prefix_warm_ttft", warm_ttft * 1e6,
         f"{reduction:.1f}x reduction")
    return {
        "system_prompt_tokens": len(sys_prompt),
        "cold_ttft_s": float(cold_ttft),
        "warm_ttft_p50_s": warm_ttft,
        "ttft_reduction": float(reduction),
        "block_hits": int(eng.backend.prefix.hits),
    }


def bench_async_overload(cfg, params, batch, max_len, block_size,
                         smoke: bool):
    """Multi-tenant OVERLOAD trace through the asyncio front end
    (serve/server.py): a Poisson burst of interactive requests (tight
    TTFT deadlines) and batch requests (no deadline) deliberately
    exceeds the queue + memory budget, so admission control MUST shed
    and deadlines MUST miss — the CI chaos-smoke job asserts both
    counters are nonzero and that the pool ends leak-free."""
    import asyncio

    from repro.serve import (
        AsyncServer,
        Request as _Req,
        ServerConfig,
        ShedError,
        assert_leak_free,
    )

    n = 12 if smoke else 48
    rng = np.random.RandomState(11)
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      backend="paged", block_size=block_size,
                      prefix_cache=False)
    eng.submit(_Req(prompt=[1, 2, 3], max_new_tokens=2))
    eng.run()  # compile warmup outside the measured window
    # Trace built up front (deterministic): bursty sub-ms arrivals into
    # a queue bounded well under the burst size.
    trace = []
    for i in range(n):
        arrive = float(rng.exponential(0.002))
        plen = int(rng.randint(4, 17))
        prompt = [int(x) for x in rng.randint(1, 200, size=plen)]
        if i % 2 == 0:  # interactive tenant: tight TTFT deadline
            # every 4th is already hopeless (0 budget) — a guaranteed,
            # environment-independent deadline miss for the CI gate
            ttft = 0.0 if i % 4 == 0 else 0.25
            trace.append((arrive, prompt, int(rng.randint(2, 7)), ttft))
        else:  # batch tenant: long budget, no deadline
            trace.append((arrive, prompt, int(rng.randint(8, 25)), None))
    scfg = ServerConfig(max_queue=max(2, batch), max_retries=1,
                        retry_backoff_s=0.005, max_demand_factor=1.5)

    async def client(srv, spec):
        arrive, prompt, max_new, ttft = spec
        await asyncio.sleep(arrive)
        try:
            return await srv.complete(prompt, max_new_tokens=max_new,
                                      ttft_deadline_s=ttft)
        except ShedError:
            return None

    async def drive():
        async with AsyncServer(eng, scfg) as srv:
            done = await asyncio.gather(
                *(client(srv, s) for s in trace))
            # Render the exporter surface while the server is still up:
            # exactly what a Prometheus scrape of /metrics would read.
            return done, srv.snapshot(), srv.metrics_text()

    t0 = time.perf_counter()
    done, snap, prom_text = asyncio.run(drive())
    makespan = time.perf_counter() - t0
    assert_leak_free(eng)  # overload must not leak a single block
    # The exporter text must round-trip through the strict parser — a
    # malformed sample line here would break a real Prometheus scrape.
    parsed = parse_prometheus(prom_text)
    assert parsed["counters"].get(
        "repro_serve_sheds_total", 0) > 0, "overload did not shed"
    sheds = snap.get("sheds", 0)
    misses = (snap.get("deadline_misses_ttft", 0)
              + snap.get("deadline_misses_total", 0))
    completed = snap.get("completed", 0)
    shed_rate = sheds / n
    miss_rate = misses / n
    print(f"async-serve   {n} req in {makespan:5.2f}s: {completed} "
          f"completed, {sheds} shed ({shed_rate:.2f}), {misses} "
          f"deadline-missed ({miss_rate:.2f}) | pool leak-free | "
          f"queue_time p99 "
          f"{snap.get('queue_time_s', {}).get('p99', 0.0) * 1e3:.1f}ms")
    emit("serve_async_shed_rate", max(shed_rate, 1e-9) * 1e6,
         f"{sheds}/{n} under overload")
    emit("serve_async_deadline_miss_rate", max(miss_rate, 1e-9) * 1e6,
         f"{misses}/{n} under overload")
    return {
        "requests": n,
        "completed": int(completed),
        "sheds": int(sheds),
        "shed_rate": float(shed_rate),
        "deadline_misses": int(misses),
        "deadline_miss_rate": float(miss_rate),
        "cancellations": int(snap.get("cancellations", 0)),
        "watchdog_stalls": int(snap.get("watchdog_stalls", 0)),
        "queue_time_p99_s": float(
            snap.get("queue_time_s", {}).get("p99", 0.0)),
        "ttft_p50_s": float(snap.get("ttft_s", {}).get("p50", 0.0)),
        "makespan_s": float(makespan),
        "leak_free": True,
        "exporter_valid": True,
        "exporter_counters": len(parsed["counters"]),
        "exporter_histograms": len(parsed["histograms"]),
        "engine_info": eng.config_info(),
    }


def bench_telemetry(cfg, params, batch, max_len, smoke: bool):
    """Model-interior telemetry phase (docs/observability.md):

    1. Serve the same greedy trace with telemetry OFF and ON — the token
       streams must be identical (the side outputs are stop_gradient'd
       stats, never part of the sampled path) and the decode program must
       not recompile. Reports the per-phase routing-health/numerics gauge
       count and the roofline-vs-measured program efficiency attribution
       (timers reset post-warmup so compile time is not attributed).
    2. The batch-variance probe three ways: on a group-routed BPR
       sparse-MoE reference (serving routes per-row, so ~0 expected —
       the ROADMAP batch-invariant-serving acceptance reading), on this
       bench's arch as configured (~0 expected), and on the same sparse
       reference with the ``batch_coupled=True`` escape hatch (old
       coupled group routing — FINITE expected, proving the instrument
       itself still detects coupling)."""
    import dataclasses

    from repro.models import lm_init as _lm_init
    from repro.serve import ServeMetrics, batch_variance_probe

    budget = 6 if smoke else 12

    def serve(telem):
        eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                          telemetry=telem)
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
        eng.run()  # compile warmup outside the attributed window
        for t in getattr(eng, "_timers", {}).values():
            t.reset()
        warm_sizes = eng.jit_cache_sizes()
        reqs = [Request(prompt=[(i + 1) * 7 % 200 + 1] * 8,
                        max_new_tokens=budget) for i in range(batch)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert eng.jit_cache_sizes() == warm_sizes, (
            "telemetry variant recompiled under churn"
        )
        return eng, [r.out for r in reqs]

    _, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off, (
        "telemetry side outputs changed the served tokens"
    )

    # Post-warmup metrics surface: warm it with a throwaway gauge, then
    # reset_counters() so only the measured run's gauges are exported.
    metrics = ServeMetrics()
    metrics.set_gauge("warmup_marker", 1.0)
    metrics.reset_counters()
    metrics.merge_gauges(eng_on.telemetry.gauges())
    eff = eng_on.program_efficiency()
    for program, ratio in eff.items():
        metrics.set_gauge("program_efficiency", ratio, program=program)
    snap = eng_on.telemetry_snapshot()
    n_gauges = sum(len(v) for v in snap.values())
    eff_s = " ".join(f"{k}={v:.2e}" for k, v in sorted(eff.items()))
    print(f"telemetry     parity OK ({sum(map(len, toks_on))} tok) | "
          f"{n_gauges} gauges over {sorted(snap)} | efficiency {eff_s}")

    # Batch-variance probe. The group-routed reference carries the knobs
    # that USED to couple rows (BPR + binding capacity + group_size =
    # batch); serving must now read ~0 on it. The batch_coupled=True
    # variant forces the old group routing so fillers can evict the
    # target row again — a finite reading there proves the instrument is
    # alive, not that serving regressed.
    ref = reduced(get_config("granite-moe-1b-a400m"))
    ref = dataclasses.replace(ref, moe=dataclasses.replace(
        ref.moe, group_size=batch, capacity_factor=0.5, bpr=True))
    ref_params = _lm_init(jax.random.PRNGKey(0), ref)
    coupled_ref = dataclasses.replace(ref, moe=dataclasses.replace(
        ref.moe, batch_coupled=True))
    # 8 probe tokens even in smoke: capacity eviction of the target row
    # often first bites a few steps in, and the reference model is tiny.
    probe_kw = dict(batch_size=batch, max_new_tokens=8,
                    max_len=min(max_len, 64))
    grouped = batch_variance_probe(ref, ref_params, [1, 2, 3, 4],
                                   **probe_kw)
    own = batch_variance_probe(cfg, params, [1, 2, 3, 4], **probe_kw)
    coupled = batch_variance_probe(coupled_ref, ref_params, [1, 2, 3, 4],
                                   **probe_kw)
    print(f"batch-variance probe: group-routed BPR sparse divergence "
          f"{grouped['divergence']:.3e} over {grouped['steps_compared']} "
          f"steps | {cfg.name if hasattr(cfg, 'name') else 'bench arch'} "
          f"divergence {own['divergence']:.3e} | batch_coupled hatch "
          f"{coupled['divergence']:.3e}")
    emit("serve_batch_variance_grouped", max(grouped["divergence"], 1e-12)
         * 1e6, "group-routed BPR sparse reference")
    emit("serve_batch_variance_own", max(own["divergence"], 1e-12) * 1e6,
         "bench arch as configured")
    emit("serve_batch_variance_coupled", max(coupled["divergence"], 1e-12)
         * 1e6, "batch_coupled=True escape hatch (instrument liveness)")
    return {
        "parity": True,
        "phases": sorted(snap),
        "gauge_count": int(n_gauges),
        "program_efficiency": {k: float(v) for k, v in eff.items()},
        "decode_sample": {
            k: round(float(v), 6)
            for k, v in sorted(snap.get("decode", {}).items())[:8]
        },
        "batch_variance": {
            "grouped_bpr_sparse": {
                "divergence": float(grouped["divergence"]),
                "steps_compared": int(grouped["steps_compared"]),
            },
            "bench_arch": {
                "divergence": float(own["divergence"]),
                "steps_compared": int(own["steps_compared"]),
            },
            "batch_coupled_hatch": {
                "divergence": float(coupled["divergence"]),
                "steps_compared": int(coupled["steps_compared"]),
            },
        },
        "exported_gauges": len(metrics.gauges),
    }


def run_bench(arch="qwen2-0.5b", requests=32, batch=4, max_len=128,
              rate=8.0, smoke=False, block_size=16, num_blocks=None):
    cfg = reduced(get_config(arch))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    trace = make_trace(requests, rate)
    if num_blocks is None:
        # capacity parity with the contiguous reservation: the reported
        # peak is the LIVE-request block footprint (tree-retained blocks
        # are reclaimable cache), so the memory ratio reflects traffic,
        # not the configured pool size
        num_blocks = batch * (-(-max_len // block_size)) + 1
    print(f"arch={arch} (reduced) requests={requests} "
          f"batch={batch} rate={rate}/s max_len={max_len} "
          f"paged pool={num_blocks - 1} x {block_size}-token blocks")

    def build(kind):
        if kind == "wave":
            return WaveEngine(cfg, params, batch_size=batch,
                              max_len=max_len)
        if kind == "contiguous":
            return ServeEngine(cfg, params, batch_size=batch,
                               max_len=max_len)
        # "paged" decodes through the Pallas paged-attention kernel (the
        # serving default); "paged_gather" is the jnp row-view oracle.
        return ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                           backend="paged", block_size=block_size,
                           num_blocks=num_blocks,
                           use_kernel=kind == "paged")

    results = {}
    for kind, tick in (("wave", wave_tick), ("continuous", continuous_tick),
                       ("paged", continuous_tick),
                       ("paged_gather", continuous_tick)):
        eng = build("contiguous" if kind == "continuous" else kind)
        # Warm THIS instance on a throwaway request: jax.jit caches are
        # per-closure, so compiles on a separate warm engine would be
        # discarded and the measured replay would pay them instead. Then
        # zero the counters so the reported stats carry only the trace.
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
        eng.run()
        eng.decode_steps = 0
        if kind.startswith("paged"):
            eng.backend.live_block_hw = 0
            eng.backend.mgr.high_water = eng.backend.mgr.num_used
            if eng.backend.prefix is not None:
                eng.backend.prefix.hits = eng.backend.prefix.misses = 0
        mk, reqs = replay(eng, trace, tick)
        results[kind] = summarize(kind, mk, reqs, eng.decode_steps,
                                  eng.peak_cache_bytes())
        if kind.startswith("paged"):
            results[kind]["pool_high_water_blocks"] = (
                eng.backend.mgr.high_water
            )
            results[kind]["live_block_high_water"] = (
                eng.backend.live_block_hw
            )
        if kind != "wave":
            sizes = eng.jit_cache_sizes()
            assert sizes[0] == 1, f"{kind} decode recompiled: {sizes}"

    prefix = bench_prefix_cache(cfg, params, batch, max_len,
                                n_warm=3 if smoke else 8)
    paged_kernel = bench_paged_kernel(
        cfg, params, batch, max_len, block_size,
        budget=8 if smoke else max(16, max_len - 32),
    )
    spec = bench_spec_decode(
        cfg, params, batch, max_len, block_size,
        budget=16 if smoke else max(24, max_len - 32),
    )
    overload = bench_async_overload(cfg, params, batch, max_len,
                                    block_size, smoke)
    telemetry = bench_telemetry(cfg, params, batch, max_len, smoke)

    speedup = results["continuous"]["tok_s"] / max(
        results["wave"]["tok_s"], 1e-9
    )
    mem_ratio = results["paged"]["peak_cache_bytes"] / max(
        results["continuous"]["peak_cache_bytes"], 1
    )
    print(f"continuous/wave throughput: {speedup:.2f}x | "
          f"paged/contiguous peak cache: {mem_ratio:.2f}x")
    emit("serve_speedup", speedup * 1e6, "continuous/wave tok/s ratio")
    emit("serve_paged_mem_ratio", mem_ratio * 1e6,
         "paged/contiguous peak cache bytes")

    # Smoke runs keep their own artifact: `benchmarks/run.py` and CI must
    # not clobber the full-trace perf trajectory with 8-request numbers.
    json_path = os.path.join(
        _REPO_ROOT, "BENCH_serve.smoke.json" if smoke else "BENCH_serve.json"
    )
    payload = {
        "bench": "serve",
        "arch": arch,
        "reduced": True,
        "requests": requests,
        "batch": batch,
        "max_len": max_len,
        "rate_req_s": rate,
        "block_size": block_size,
        "num_blocks": num_blocks,
        "smoke": smoke,
        "engines": results,
        "prefix_cache": prefix,
        "paged_attention_kernel": paged_kernel,
        "spec_decode": spec,
        "async_overload": overload,
        "telemetry": telemetry,
        # Frozen engine config of the overload engine — the same labels
        # the exporter serves as the `repro_serve_engine_info` gauge.
        "engine_info": overload["engine_info"],
        "continuous_over_wave_tok_s": float(speedup),
        "paged_over_contiguous_peak_cache": float(mem_ratio),
    }
    # Cross-PR perf trajectory: the latest-run summary stays at the top
    # level, but each run also APPENDS a compact record to `history`, so
    # the trajectory is never lost to a wholesale overwrite (before this,
    # every run clobbered the previous numbers and the trajectory was
    # unrecoverable).
    history = []
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                history = json.load(f).get("history", [])
        except (json.JSONDecodeError, OSError):
            pass  # corrupt/legacy artifact: start the trajectory fresh
    history.append({
        "rev": _git_rev(),
        "date": time.strftime("%Y-%m-%d %H:%M:%S", time.gmtime()),
        # Engine config: perf numbers are meaningless across history
        # rows without the pool geometry they ran under.
        "block_size": block_size,
        "num_blocks": num_blocks,
        "max_batch": batch,
        "continuous_tok_s": round(results["continuous"]["tok_s"], 1),
        "paged_tok_s": round(results["paged"]["tok_s"], 1),
        "latency_p50_s": round(results["paged"]["latency_p50_s"], 4),
        "latency_p99_s": round(results["paged"]["latency_p99_s"], 4),
        "spec_acceptance_rate": (
            round(spec["acceptance_rate"], 3) if spec else None
        ),
        "spec_calls_per_token": (
            round(spec["decode_calls_per_token_spec"], 3) if spec else None
        ),
        "shed_rate": round(overload["shed_rate"], 3),
        "deadline_miss_rate": round(overload["deadline_miss_rate"], 3),
        "exporter_metrics": (overload["exporter_counters"]
                             + overload["exporter_histograms"]),
        # Roofline-vs-measured attribution + the batch-variance probe:
        # the trajectory of these is the point. Both served-arch rows
        # must stay ~0 forever (a finite value is a batch-invariance
        # regression); the coupled-hatch row must stay finite (a zero
        # means the instrument died).
        "decode_efficiency": round(
            telemetry["program_efficiency"].get("decode", 0.0), 6),
        "batch_variance_grouped": round(
            telemetry["batch_variance"]["grouped_bpr_sparse"]["divergence"],
            6),
        "batch_variance_own": round(
            telemetry["batch_variance"]["bench_arch"]["divergence"], 6),
        "batch_variance_coupled": round(
            telemetry["batch_variance"]["batch_coupled_hatch"]["divergence"],
            6),
    })
    payload["history"] = history
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(json_path)}")

    if not smoke:
        if speedup <= 1.0:
            raise SystemExit("continuous batching did not beat wave")
        if prefix is not None and prefix["ttft_reduction"] < 2.0:
            raise SystemExit(
                f"prefix cache TTFT reduction {prefix['ttft_reduction']:.2f}x "
                "< 2x acceptance bar"
            )
        # The tok/s bar applies where the kernel actually compiles to
        # hardware; in interpret mode (CPU CI) wall-clock measures the
        # Pallas emulator, not the kernel (see module docstring) — there
        # the gates are greedy parity (asserted above) + the modeled
        # traffic saving + the no-materialization proof (bench_kernels).
        if (paged_kernel is not None
                and not paged_kernel["emulated_interpret"]
                and paged_kernel["kernel_over_gather_tok_s"] < 1.0):
            raise SystemExit(
                f"paged-attention kernel decode "
                f"{paged_kernel['decode_tok_s_kernel']:.1f} tok/s < gather "
                f"{paged_kernel['decode_tok_s_gather']:.1f} tok/s"
            )
        if (paged_kernel is not None
                and paged_kernel["modeled_hbm_traffic_saving"] < 2.0):
            raise SystemExit("kernel HBM model lost its 3x saving")
        # Speculation must beat one-model-call-per-token arithmetically
        # on the decode-saturated trace; the wall-clock gate arms only
        # where the plain baseline's kernel actually compiles to
        # hardware (same caveat as the paged-kernel phase).
        if spec is not None:
            if spec["decode_calls_per_token_spec"] >= 1.0:
                raise SystemExit(
                    f"speculative decoding made "
                    f"{spec['decode_calls_per_token_spec']:.2f} model "
                    "calls/token (>= 1.0: drafts never accepted)"
                )
            if (not spec["emulated_interpret"]
                    and spec["spec_over_plain_tok_s"] < 1.0):
                raise SystemExit(
                    f"spec decode {spec['decode_tok_s_spec']:.1f} tok/s < "
                    f"plain {spec['decode_tok_s_plain']:.1f}"
                )
        # The overload phase is only meaningful if it actually
        # overloaded: zero sheds or zero deadline misses means the
        # burst fit the budget and nothing was exercised.
        if overload["sheds"] == 0 or overload["deadline_misses"] == 0:
            raise SystemExit(
                f"async overload phase failed to overload "
                f"(sheds={overload['sheds']}, "
                f"deadline_misses={overload['deadline_misses']})"
            )
        # Batch-invariance acceptance gates: EVERY served arch must read
        # ~0 on the probe — the group-routed BPR sparse reference (the
        # historical worst case) and this bench's arch alike. The
        # batch_coupled=True escape hatch must read finite, or the
        # instrument itself is dead and the ~0 readings prove nothing.
        tv = telemetry["batch_variance"]
        if tv["grouped_bpr_sparse"]["divergence"] >= 1e-5:
            raise SystemExit(
                f"batch-variance probe read "
                f"{tv['grouped_bpr_sparse']['divergence']:.3e} on the "
                "group-routed BPR sparse reference — serving routing is "
                "batch-coupled again"
            )
        if tv["bench_arch"]["divergence"] >= 1e-5:
            raise SystemExit(
                f"batch-variance probe read "
                f"{tv['bench_arch']['divergence']:.3e} on the bench arch"
            )
        if tv["batch_coupled_hatch"]["divergence"] <= 0.0:
            raise SystemExit(
                "batch-variance probe read 0 with batch_coupled=True — "
                "capacity competition never reached the target row and "
                "the instrument is dead"
            )
    return payload


def run():
    """benchmarks/run.py entry point (CI-sized)."""
    run_bench(requests=8, smoke=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool size (default: parity with the "
                         "contiguous reservation)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 8 requests, no perf gates")
    args = ap.parse_args()
    run_bench(arch=args.arch,
              requests=8 if args.smoke else args.requests,
              batch=args.batch, max_len=args.max_len, rate=args.rate,
              smoke=args.smoke, block_size=args.block_size,
              num_blocks=args.num_blocks)


if __name__ == "__main__":
    main()
