"""Continuous vs wave batching under a Poisson arrival trace.

Replays one fixed trace of mixed-length requests (Poisson arrivals,
uniform prompt lengths and token budgets) through both engines and
reports throughput (generated tokens / makespan) and per-request latency
(submit -> done) percentiles:

  PYTHONPATH=src python benchmarks/bench_serve.py            # full trace
  PYTHONPATH=src python benchmarks/bench_serve.py --smoke    # CI-sized

The wave engine admits up to `batch` queued requests, decodes the whole
wave in lockstep until its longest row finishes, and only then admits
again — a finished row's slot idles, and a request arriving mid-wave
waits for the boundary. The continuous engine retires rows and admits
replacements every tick, so the same trace finishes in fewer model calls
and each request's latency tracks its own length, not its wave's.

Emits `name,us_per_call,derived` rows (benchmarks/common.py contract)
plus a human-readable summary.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.models import lm_init  # noqa: E402
from repro.serve import (  # noqa: E402
    Request,
    SamplingParams,
    ServeEngine,
    WaveEngine,
)


def make_trace(n_requests: int, rate: float, seed: int = 0):
    """(arrival_time, prompt, max_new, sampling) tuples; Poisson arrivals
    at `rate` req/s, prompt len U[4,24], budget U[4,32], a mix of greedy
    and temperature/top-k/top-p rows."""
    rng = np.random.RandomState(seed)
    t = 0.0
    trace = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.randint(4, 25))
        prompt = [int(x) for x in rng.randint(1, 200, size=plen)]
        max_new = int(rng.randint(4, 33))
        if i % 3 == 0:
            sp = SamplingParams()  # greedy
        elif i % 3 == 1:
            sp = SamplingParams(temperature=0.8, top_k=40, seed=i)
        else:
            sp = SamplingParams(temperature=1.0, top_p=0.9, seed=i)
        trace.append((t, prompt, max_new, sp))
    return trace


def replay(engine, trace, tick):
    """Drive `engine` against wall-clock arrivals; returns (makespan_s,
    requests). `tick(engine)` advances the engine one step when work is
    available."""
    reqs = [
        Request(prompt=p, max_new_tokens=m, sampling=sp)
        for (_, p, m, sp) in trace
    ]
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            engine.submit(reqs[i])
            i += 1
        if all(r.done for r in reqs):
            break
        if not tick(engine):
            if i < len(trace):  # idle: wait for the next arrival
                time.sleep(min(0.001, trace[i][0] - now))
    return time.perf_counter() - t0, reqs


def continuous_tick(eng):
    if eng.sched.pending():
        eng.step()
        return True
    return False


def wave_tick(eng):
    if eng.queue:
        eng.run()  # drains currently-queued waves; late arrivals wait
        return True
    return False


def summarize(label, makespan, reqs, decode_steps):
    total_tokens = sum(len(r.out) for r in reqs)
    lat = np.array([r.t_done - r.t_submit for r in reqs])
    tps = total_tokens / makespan
    p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
    print(f"{label:12s} {total_tokens:5d} tok in {makespan:6.2f}s "
          f"-> {tps:7.1f} tok/s | latency p50 {p50*1e3:7.1f}ms "
          f"p99 {p99*1e3:7.1f}ms | {decode_steps} decode calls")
    emit(f"serve_{label}_tok_s", 1e6 / max(tps, 1e-9), f"{tps:.1f} tok/s")
    emit(f"serve_{label}_p50", p50 * 1e6, "per-request latency")
    emit(f"serve_{label}_p99", p99 * 1e6, "per-request latency")
    return tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 8 requests, skips nothing else")
    args = ap.parse_args()
    if args.smoke:
        args.requests = 8

    cfg = reduced(get_config(args.arch))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    trace = make_trace(args.requests, args.rate)
    print(f"arch={args.arch} (reduced) requests={args.requests} "
          f"batch={args.batch} rate={args.rate}/s")

    # Warm both engines on a throwaway request so compile time (identical
    # one-off cost for both) does not skew the trace replay.
    for build in (
        lambda: ServeEngine(cfg, params, batch_size=args.batch,
                            max_len=args.max_len),
        lambda: WaveEngine(cfg, params, batch_size=args.batch,
                           max_len=args.max_len),
    ):
        eng = build()
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
        eng.run()

    wave = WaveEngine(cfg, params, batch_size=args.batch,
                      max_len=args.max_len)
    mk_w, reqs_w = replay(wave, trace, wave_tick)
    tps_w = summarize("wave", mk_w, reqs_w, wave.decode_steps)

    cont = ServeEngine(cfg, params, batch_size=args.batch,
                       max_len=args.max_len)
    mk_c, reqs_c = replay(cont, trace, continuous_tick)
    tps_c = summarize("continuous", mk_c, reqs_c, cont.decode_steps)

    assert cont._decode._cache_size() == 1, "decode recompiled mid-trace"
    speedup = tps_c / max(tps_w, 1e-9)
    print(f"continuous/wave throughput: {speedup:.2f}x")
    emit("serve_speedup", speedup * 1e6, "continuous/wave tok/s ratio")
    if not args.smoke and speedup <= 1.0:
        raise SystemExit("continuous batching did not beat wave batching")


if __name__ == "__main__":
    main()
