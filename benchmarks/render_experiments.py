"""Render EXPERIMENTS.md §Dry-run and §Roofline sections from the dry-run
JSONL files (single + multi pod). §Perf is hand-written (hypothesis logs).
"""
from __future__ import annotations

import json
import sys


def load(path):
    rows = {}
    try:
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    rows[r["cell"]] = r  # last write wins (retries)
                except json.JSONDecodeError:
                    pass
    except FileNotFoundError:
        pass
    return rows


def dryrun_table(rows):
    out = [
        "| cell | status | compile | args/dev | temp/dev (raw → TPU-corr) |"
        " collectives (count) |",
        "|---|---|---|---|---|---|",
    ]
    for cell, r in rows.items():
        if r["status"] == "skipped":
            out.append(f"| {cell} | SKIP | — | — | — | {r['reason'][:70]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {cell} | **ERROR** | — | — | — |"
                       f" {r.get('error', '')[:70]} |")
            continue
        m, c = r["memory"], r["collectives"]
        counts = ", ".join(f"{k}:{v}" for k, v in sorted(c["counts"].items()))
        out.append(
            f"| {cell} | ok | {r['compile_s']}s "
            f"| {m['args_bytes_per_dev']/1e9:.2f}GB "
            f"| {m['temp_bytes_per_dev']/1e9:.1f} → "
            f"{m['tpu_corrected_temp_bytes']/1e9:.1f}GB "
            f"| {counts} |"
        )
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| cell | t_compute | t_memory | t_collective | bottleneck |"
        " roofline frac | MODEL/HLO FLOPs | what moves the bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell, r in rows.items():
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        hint = _hint(r)
        out.append(
            f"| {cell} | {rf['t_compute_s']*1e3:.2f}ms "
            f"| {rf['t_memory_s']*1e3:.2f}ms "
            f"| {rf['t_collective_s']*1e3:.2f}ms | {rf['bottleneck']} "
            f"| {rf['roofline_fraction']:.3f} "
            f"| {rf['useful_flops_fraction']:.2f} | {hint} |"
        )
    return "\n".join(out)


def _hint(r) -> str:
    rf = r["roofline"]
    b = rf["bottleneck"]
    c = r["collectives"]["by_kind_bytes"]
    if b == "collective":
        top = max(c, key=c.get) if c else "?"
        return f"cut {top} bytes (sharding/TP width/overlap)"
    if b == "memory":
        return "decode: batch more sequences per chip / quantize KV"
    return "compute-bound: at roofline, tune MXU tiling"


def main():
    single = load(sys.argv[1] if len(sys.argv) > 1 else
                  "results/dryrun_single_pod.jsonl")
    multi = load(sys.argv[2] if len(sys.argv) > 2 else
                 "results/dryrun_multi_pod.jsonl")
    print("## §Dry-run — single-pod 16×16 (256 chips)\n")
    print(dryrun_table(single))
    print("\n## §Dry-run — multi-pod 2×16×16 (512 chips)\n")
    print(dryrun_table(multi))
    print("\n## §Roofline — single-pod baselines\n")
    print(roofline_table(single))
    print("\n## §Roofline — multi-pod\n")
    print(roofline_table(multi))


if __name__ == "__main__":
    main()
