"""Paper Appendix B: token dropping vs expert count for the sparse routers
(C=1 tight buffers), and Soft MoE's structural zero."""
from __future__ import annotations

import jax

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init

from .common import emit


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256, 64))
    for variant in ("tokens_choice", "experts_choice"):
        for n in (8, 16, 32, 64, 128):
            cfg = MoEConfig(variant=variant, num_experts=n, expert_d_ff=64,
                            top_k=1, capacity_factor=1.0, group_size=4,
                            bpr=False)
            params = moe_init(jax.random.PRNGKey(1), 64, cfg)
            _, m = moe_apply(params, cfg, x)
            emit(f"appB_dropping/{variant}/{n}e", 0.0,
                 f"dropped={float(m['dropped_fraction']):.3f}")
    # BPR effect (paper Fig. 15): fewer effective drops among high-score
    cfg = MoEConfig(variant="tokens_choice", num_experts=64, expert_d_ff=64,
                    top_k=1, capacity_factor=1.0, group_size=4, bpr=True)
    params = moe_init(jax.random.PRNGKey(1), 64, cfg)
    _, m = moe_apply(params, cfg, x)
    emit("appB_dropping/tokens_choice_bpr/64e", 0.0,
         f"dropped={float(m['dropped_fraction']):.3f}")
    # Soft MoE: zero by construction
    cfg = MoEConfig(variant="soft", num_experts=64, expert_d_ff=64)
    params = moe_init(jax.random.PRNGKey(1), 64, cfg)
    _, m = moe_apply(params, cfg, x)
    emit("appB_dropping/soft/64e", 0.0, "dropped=0.000 (by construction)")


if __name__ == "__main__":
    run()
