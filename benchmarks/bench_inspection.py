"""Paper §5 / Fig. 9 (structural): routing statistics of a Soft-MoE layer
after a short training run — token-contribution tail, expert-importance
spread, tokens-per-slot coverage."""
from __future__ import annotations

import jax

from repro.configs import reduced, soft_moe_vit
from repro.core.inspection import routing_stats, summarize
from repro.data import SyntheticImages
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

from .common import emit


def run():
    cfg = reduced(soft_moe_vit("s", 16, 8))
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(0), init)
    data = SyntheticImages(num_patches=cfg.frontend.num_embeds,
                           patch_dim=cfg.frontend.embed_dim,
                           batch_size=16, num_classes=32, seed=5)
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, schedule="constant",
                           total_steps=10**9, cooldown_steps=1)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    for s in range(60):
        state, _ = step(state, data.batch(s))

    # inspect the first MoE layer's routing on fresh data
    moe_params = jax.tree_util.tree_map(
        lambda a: a[0], state["params"]["segments"][1]
    )["moe"]
    batch = data.batch(999)
    x = batch["patches"] @ state["params"]["patch_proj"]["w"]
    stats = summarize(routing_stats(x, moe_params, cfg.moe))
    for k in ("token_contribution_min", "token_contribution_max",
              "expert_importance_spread", "tokens_for_50pct_mean",
              "tokens_for_90pct_mean", "max_dispatch_weight",
              "max_combine_weight"):
        emit(f"fig9_inspection/{k}", 0.0, f"value={stats[k]:.3f}")


if __name__ == "__main__":
    run()
