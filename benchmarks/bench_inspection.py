"""Paper §5 / Fig. 9 (structural): routing statistics of a Soft-MoE layer
after a short training run — token-contribution tail, expert-importance
spread, tokens-per-slot coverage."""
from __future__ import annotations

import jax

from repro.configs import reduced, soft_moe_vit
from repro.core.inspection import routing_stats, summarize
from repro.data import SyntheticImages
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

from .common import emit


def run():
    cfg = reduced(soft_moe_vit("s", 16, 8))
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(0), init)
    data = SyntheticImages(num_patches=cfg.frontend.num_embeds,
                           patch_dim=cfg.frontend.embed_dim,
                           batch_size=16, num_classes=32, seed=5)
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, schedule="constant",
                           total_steps=10**9, cooldown_steps=1)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    for s in range(60):
        state, _ = step(state, data.batch(s))

    # inspect the first MoE layer's routing on fresh data
    moe_params = jax.tree_util.tree_map(
        lambda a: a[0], state["params"]["segments"][1]
    )["moe"]
    batch = data.batch(999)
    x = batch["patches"] @ state["params"]["patch_proj"]["w"]
    stats = summarize(routing_stats(x, moe_params))
    for k in ("token_contribution_min", "token_contribution_max",
              "expert_importance_spread", "tokens_for_50pct_mean",
              "tokens_for_90pct_mean", "max_dispatch_weight",
              "max_combine_weight"):
        emit(f"fig9_inspection/{k}", 0.0, f"value={stats[k]:.3f}")

    # serving-shape path: streamed softmax stats, no (m × S) weights —
    # must agree with the dense oracle above wherever keys overlap
    chunked = summarize(routing_stats(x, moe_params, method="chunked",
                                      chunk_tokens=16))
    worst = max(abs(chunked[k] - stats[k]) for k in chunked if k in stats)
    assert worst < 1e-3, f"chunked inspection drifted from oracle: {worst}"
    emit("fig9_inspection/chunked_vs_dense_max_abs_diff", 0.0,
         f"value={worst:.2e}")

    # Export the same stats through the serving metrics surface and
    # round-trip the exposition: gauges set during warmup are wiped by
    # reset_counters() so the scrape carries only final-state values.
    from repro.serve import ServeMetrics, parse_prometheus, render_prometheus

    metrics = ServeMetrics()
    metrics.set_gauge("inspection_token_contribution_min", -1.0)  # warmup
    metrics.reset_counters()
    for k, v in stats.items():
        metrics.set_gauge(f"inspection_{k}", float(v))
    parsed = parse_prometheus(render_prometheus(metrics))
    got = parsed["gauges"][
        "repro_serve_inspection_token_contribution_min"][1]
    want = float(stats["token_contribution_min"])
    # the exposition renders 12 significant digits; f32 stats carry ~7
    assert abs(got - want) <= 1e-9 * max(1.0, abs(want)), (
        "inspection gauge did not survive the exporter round-trip"
    )


if __name__ == "__main__":
    run()
