"""Benchmark harness — one module per paper table/figure.

  * bench_experts_scaling — Fig. 6/7 (step time vs experts, fixed slots)
  * bench_dropping        — App. B (token dropping, sparse vs soft)
  * bench_ablations       — Table 3 (routing ablations ordering)
  * bench_pareto          — Fig. 3 (cost/quality points, micro)
  * bench_kernels         — fused kernel HBM-traffic model + jnp timing
  * bench_inspection      — §5/Fig. 9 routing statistics
  * bench_serve           — wave/contiguous/paged engines + prefix cache
                            (CI-sized here, writing BENCH_serve.smoke.json;
                            run `benchmarks/bench_serve.py` directly for
                            the full trace that refreshes BENCH_serve.json)

Prints ``name,us_per_call,derived`` CSV. Roofline tables render separately
via ``python -m benchmarks.roofline_table results/<file>.jsonl``.
"""
from __future__ import annotations

import sys


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from . import (
        bench_ablations,
        bench_dropping,
        bench_experts_scaling,
        bench_inspection,
        bench_kernels,
        bench_pareto,
        bench_serve,
    )

    mods = {
        "experts_scaling": bench_experts_scaling,
        "dropping": bench_dropping,
        "ablations": bench_ablations,
        "pareto": bench_pareto,
        "kernels": bench_kernels,
        "inspection": bench_inspection,
        "serve": bench_serve,
    }
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and only != name:
            continue
        mod.run()


if __name__ == "__main__":
    main()
