"""Paper Table 3: routing ablations (Soft / Soft-Uniform / Uniform-Soft /
Uniform / Identity / Dense) trained identically on the synthetic image
task; reproduces the ORDERING of the table at reduced scale."""
from __future__ import annotations

import dataclasses

import jax

from repro.configs import reduced, soft_moe_vit, vit
from repro.data import SyntheticImages
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

from .common import emit

STEPS = 150


def _final_loss(cfg, seed=0):
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(seed), init)
    # 32 effective classes: learnable within ~150 CPU steps, so the
    # Table-3 ordering resolves above fp noise
    data = SyntheticImages(num_patches=cfg.frontend.num_embeds,
                           patch_dim=cfg.frontend.embed_dim,
                           batch_size=16, num_classes=32, seed=7)
    ocfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, schedule="constant",
                           total_steps=10**9, cooldown_steps=1)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    last = []
    for s in range(STEPS):
        state, m = step(state, data.batch(s))
        if s >= STEPS - 10:
            last.append(float(m["total_loss"]))
    return sum(last) / len(last)


def run():
    base = reduced(soft_moe_vit("s", 16, 8))
    results = {}
    for variant in ("soft", "soft_uniform", "uniform_soft", "uniform",
                    "identity"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, variant=variant)
        )
        results[variant] = _final_loss(cfg)
        emit(f"table3_ablation/{variant}", 0.0,
             f"final_loss={results[variant]:.4f}")
    dense = reduced(vit("s", 16))
    results["dense"] = _final_loss(dense)
    emit("table3_ablation/dense", 0.0, f"final_loss={results['dense']:.4f}")
    ordered = results["soft"] <= results["uniform"] + 0.05
    emit("table3_ordering_soft_beats_uniform", 0.0, f"holds={ordered}")


if __name__ == "__main__":
    run()
