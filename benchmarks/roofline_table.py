"""Render the §Roofline table (EXPERIMENTS.md) from dry-run JSONL output."""
from __future__ import annotations

import json
import sys


def load(path):
    rows = []
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def fmt(rows):
    out = []
    out.append(
        "| cell | mesh | t_compute | t_memory | t_collective | bottleneck |"
        " roofline frac | useful FLOPs | temp/dev (TPU-corr) |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") == "skipped":
            out.append(
                f"| {r['cell']} | — | — | — | — | SKIP | — | — |"
                f" {r['reason'][:60]} |"
            )
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['cell']} | {r.get('mesh')} | ERROR: "
                       f"{r.get('error', '?')[:80]} | | | | | | |")
            continue
        rf = r["roofline"]
        mem = r["memory"]
        out.append(
            f"| {r['cell']} | {r['mesh']} "
            f"| {rf['t_compute_s']*1e3:.1f}ms | {rf['t_memory_s']*1e3:.1f}ms "
            f"| {rf['t_collective_s']*1e3:.1f}ms | {rf['bottleneck']} "
            f"| {rf['roofline_fraction']:.3f} "
            f"| {rf['useful_flops_fraction']:.2f} "
            f"| {mem.get('tpu_corrected_temp_bytes', 0)/1e9:.1f}GB |"
        )
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single_pod.jsonl"
    print(fmt(load(path)))


if __name__ == "__main__":
    main()
