"""Kernel-level benchmark: fused Pallas routing path vs the jnp path.

Times BOTH directions — forward and backward via ``jax.grad`` — through
``moe_apply`` for the jnp path and the fused kernel path. On this CPU
container the kernels run in interpret mode (slow by construction), so the
wall-clock column is an emulation artifact there; the analytic HBM-traffic
model is the quantity the fusion exists for (no (m × S) logit/weight
tensor touches HBM in either direction — verified structurally by
``assert_no_ms_materialization`` below, which walks the jaxpr of the
gradient computation).
"""
from __future__ import annotations

import jax

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init

from .common import emit, time_fn

F32, BF16 = 4, 2


def traffic_bytes_fwd(m, d, s, fused: bool) -> int:
    """HBM bytes for the dispatch+combine forward (bf16 acts, f32 logits).

    Unfused materializes the (m × s) logits once per softmax direction
    plus both weight tensors (each written and read back).  Fused streams
    tiles: x is read twice (routing + combine-apply), phi twice, slots
    written+read around the experts, y written once; the per-direction
    softmax stats are O(m + s) f32 — negligible but counted.
    """
    x = m * d * BF16
    phi = d * s * BF16
    slots = s * d * BF16
    y = m * d * BF16
    stats = 2 * (m + s) * F32
    if fused:
        return 2 * x + 2 * phi + 2 * slots + y + stats
    logits = m * s * F32
    weights = m * s * F32
    # logits w+r per direction, weights w+r per direction
    return 2 * x + 2 * phi + 2 * slots + y + 2 * (logits + weights) * 2


def traffic_bytes_bwd(m, d, s, fused: bool) -> int:
    """HBM bytes for the backward through dispatch+combine.

    Fused (flash-style): four kernel passes (dx and dphi-side per
    direction), each re-reading x and phi tiles and the incoming
    gradient, writing dx twice, dys once, dphi twice; weights are
    recomputed tile-wise from the O(m + s) residual stats.

    Unfused (the seed's ref-VJP): re-runs the ref forward (logits + both
    weight tensors materialized again) and then reads the stored (m × s)
    weights twice each in the bwd einsums, writing the (m × s) dlogits
    per direction as well.
    """
    x = m * d * BF16
    phi = d * s * BF16
    slots = s * d * BF16
    y = m * d * BF16
    if fused:
        stats = 2 * (m + s) * F32
        # dx kernels: (x, phi, g, stats) in, dx out — per direction.
        dx_passes = 2 * (x + phi + y + stats) + 2 * x
        # dphi/dys kernels: same tiles in, dphi (+ dys for combine) out.
        dphi_passes = 2 * (x + phi + y + stats) + 2 * phi + slots
        return dx_passes + dphi_passes
    logits = m * s * F32
    weights = m * s * F32
    recompute = 2 * (logits + weights) * 2  # ref fwd re-run, w+r each
    bwd_reads = 2 * weights * 2 + 2 * logits * 2  # weights read, dlogits w+r
    return recompute + bwd_reads + 3 * x + 2 * phi + 2 * slots + 2 * y


def materialized_ms_shapes(fn, *args, m: int, s: int, m_pad: int = 0,
                           s_pad: int = 0):
    """Shapes of any intermediate carrying a full (m × s) plane (modulo
    block padding) anywhere in the jaxpr of ``fn`` — the tensors the
    fused path exists to eliminate. ``m_pad``/``s_pad`` are the
    block-padded extents the kernels actually use (derive them from the
    same KernelConfig as the kernel call; 0 = unpadded only).

    Thin wrapper over the repo's ONE jaxpr walker
    (`repro.analysis.materialized_shapes`) so this CI proof and the
    static-analysis passes can never diverge."""
    from repro.analysis import ShapeRule, materialized_shapes

    rule = ShapeRule((m, m_pad or m), (s, s_pad or s), "(m × s) plane")
    return materialized_shapes(jax.make_jaxpr(fn)(*args).jaxpr, rule)


def assert_no_ms_materialization(fn, *args, m: int, s: int, m_pad: int = 0,
                                 s_pad: int = 0):
    shapes = materialized_ms_shapes(fn, *args, m=m, s=s, m_pad=m_pad,
                                    s_pad=s_pad)
    assert not shapes, f"(m × s) tensors materialized: {shapes}"


def run():
    b, m, d = 4, 256, 256
    for n in (64, 256):
        cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=512)
        params = moe_init(jax.random.PRNGKey(0), d, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, m, d))
        s = n * cfg.slots_per_expert

        def fwd(p, xx, *, _c=cfg, _k=False):
            return moe_apply(p, _c, xx, use_kernel=_k)[0]

        def loss(p, xx, *, _c=cfg, _k=False):
            return (moe_apply(p, _c, xx, use_kernel=_k)[0] ** 2).mean()

        for fused in (False, True):
            tag = "fused" if fused else "jnp"
            fwd_us = time_fn(
                jax.jit(lambda p, xx: fwd(p, xx, _k=fused)), params, x
            )
            bwd_us = time_fn(
                jax.jit(jax.grad(lambda p, xx: loss(p, xx, _k=fused))),
                params, x,
            )
            tf = traffic_bytes_fwd(m, d, s, fused)
            tb = traffic_bytes_bwd(m, d, s, fused)
            ratio = ((traffic_bytes_fwd(m, d, s, False)
                      + traffic_bytes_bwd(m, d, s, False)) / (tf + tb))
            emit(f"kernel_softmoe_{tag}_fwd/{n}e", fwd_us,
                 f"hbm_bytes={tf}")
            emit(f"kernel_softmoe_{tag}_bwd/{n}e", bwd_us,
                 f"hbm_bytes={tb}"
                 + ("" if not fused else f" saving={ratio:.2f}x"))

    check_materialization()
    check_paged_materialization()


def check_materialization(verbose: bool = True):
    """Structural proof that the fused train path (fwd + bwd) never
    materializes an (m × s) tensor, while the jnp path does.

    Dims are chosen pairwise-distinct (m=320, d=160, s=48, d_ff=224, b=3)
    so an m-sized or s-sized axis in the jaxpr can only be the token or
    slot axis — no collisions with d / expert / batch axes.
    """
    m, d, n, b = 320, 160, 48, 3
    cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=224)
    s = n * cfg.slots_per_expert
    params = moe_init(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, m, d))
    # padded extents from the SAME config the layer's kernel call resolves,
    # so the detector tracks the real tiling rather than assuming 128.
    from repro.kernels.tuning import config_from_moe

    kc = config_from_moe(cfg, m=m, d=d)
    m_pad = -(-m // kc.block_tokens) * kc.block_tokens
    s_pad = -(-s // kc.block_slots) * kc.block_slots

    def loss(p, *, _k):
        return (moe_apply(p, cfg, x, use_kernel=_k)[0] ** 2).mean()

    assert_no_ms_materialization(
        jax.grad(lambda p: loss(p, _k=True)), params, m=m, s=s,
        m_pad=m_pad, s_pad=s_pad)
    ms = materialized_ms_shapes(
        jax.grad(lambda p: loss(p, _k=False)), params, m=m, s=s)
    assert ms, "jnp path should materialize (m × s) logits/weights"
    if verbose:
        emit("kernel_softmoe_materialization", 0.0,
             f"fused=none jnp={len(ms)}_tensors")


def check_paged_materialization(verbose: bool = True):
    """Structural proof for the serving decode hot path: with the
    paged-attention kernel on, the paged decode program's jaxpr carries
    NO (B, blocks_per_row * block_size) tensor — `_paged_view`'s
    per-step row-view gather is gone — while the jnp-gather oracle
    materializes it. Same jaxpr-walk methodology as the Soft-MoE proof
    above; the (B, view_len) pair stands in for (m, s).

    Dims (b=3, blocks_per_row=7, block_size=16 -> view_len=112) are
    chosen so neither 3 nor 112 collides with any reduced-llama3 model
    axis (d_model 64, heads 4, head_dim 16, vocab 256).
    """
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import lm_init
    from repro.serve.block_manager import init_paged_cache
    from repro.serve.programs import make_decode_step_paged

    cfg = reduced(get_config("llama3-8b"))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    b, nb, bs = 3, 7, 16
    view_len = nb * bs
    cache = init_paged_cache(cfg, b * nb + 1, bs, b, dtype=jnp.bfloat16)
    toks = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    tables = jnp.zeros((b, nb), jnp.int32)
    assert_no_ms_materialization(
        make_decode_step_paged(cfg, use_kernel=True),
        params, toks, pos, tables, cache, m=b, s=view_len)
    ms = materialized_ms_shapes(
        make_decode_step_paged(cfg, use_kernel=False),
        params, toks, pos, tables, cache, m=b, s=view_len)
    assert ms, "gather oracle should materialize the (B, L) row view"
    if verbose:
        emit("paged_decode_materialization", 0.0,
             f"kernel=none gather={len(ms)}_tensors")


if __name__ == "__main__":
    run()
