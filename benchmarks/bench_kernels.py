"""Kernel-level benchmark: fused Pallas dispatch/combine vs the jnp path.

On this CPU container the kernels run in interpret mode (slow by
construction), so wall-time is measured for the JNP path only; the kernel
row reports the analytic HBM-traffic saving — the quantity the fusion
exists for (logits never hit HBM; see kernels/soft_moe_kernels.py).
"""
from __future__ import annotations

import jax

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init

from .common import emit, time_fn


def _traffic_bytes(m, d, s, fused: bool) -> int:
    """HBM bytes for dispatch+combine weight computation (bf16 acts,
    f32 logits): unfused materializes logits (m·s) twice + weights twice."""
    x = m * d * 2
    phi = d * s * 2
    slots = s * d * 2
    y = m * d * 2
    if fused:
        # x read twice (dispatch+combine), phi twice, slots w+r, y write
        return 2 * x + 2 * phi + 2 * slots + y
    logits = m * s * 4
    weights = m * s * 4
    # logits w+r per direction, weights w+r per direction
    return 2 * x + 2 * phi + 2 * slots + y + 2 * (logits + weights) * 2


def run():
    m, d = 256, 256
    for n in (64, 256):
        cfg = MoEConfig(variant="soft", num_experts=n, expert_d_ff=512)
        params = moe_init(jax.random.PRNGKey(0), d, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, m, d))
        jnp_fn = jax.jit(
            lambda p, xx, _c=cfg: moe_apply(p, _c, xx, use_kernel=False)[0]
        )
        us = time_fn(jnp_fn, params, x)
        s = n * cfg.slots_per_expert
        unfused = _traffic_bytes(m, d, s, fused=False)
        fused = _traffic_bytes(m, d, s, fused=True)
        emit(f"kernel_softmoe_jnp/{n}e", us,
             f"hbm_bytes={unfused}")
        emit(f"kernel_softmoe_fused/{n}e", 0.0,
             f"hbm_bytes={fused} saving={unfused / fused:.2f}x")


if __name__ == "__main__":
    run()
