"""Paper Figure 3 (micro): training-cost / performance pareto points for
Dense ViT vs Soft MoE vs Experts/Tokens Choice at matched step budgets —
reduced scale; the claim is Soft MoE dominating at equal cost."""
from __future__ import annotations

import dataclasses

import jax

from repro.configs import reduced, soft_moe_vit, vit
from repro.data import SyntheticImages
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

from .common import emit, time_fn

STEPS = 100


def _train_point(cfg, name):
    init, loss_fn, _ = build_model(cfg)
    state = init_train_state(jax.random.PRNGKey(0), init)
    data = SyntheticImages(num_patches=cfg.frontend.num_embeds,
                           patch_dim=cfg.frontend.embed_dim,
                           batch_size=16, num_classes=32, seed=11)
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup_steps=5, schedule="constant",
                           total_steps=10**9, cooldown_steps=1)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    us = time_fn(step, state, data.batch(0))  # step cost
    accs = []
    for s in range(STEPS):
        state, m = step(state, data.batch(s))
        if s >= STEPS - 10:
            accs.append(float(m.get("accuracy", 0.0)))
    emit(f"fig3_pareto/{name}", us,
         f"acc={sum(accs)/len(accs):.3f}")


def run():
    _train_point(reduced(vit("s", 16)), "dense_vit_s16")
    base = reduced(soft_moe_vit("s", 16, 8))
    _train_point(base, "soft_moe_8e")
    for variant in ("experts_choice", "tokens_choice"):
        cfg = dataclasses.replace(
            base,
            moe=dataclasses.replace(base.moe, variant=variant, top_k=1,
                                    capacity_factor=1.0),
        )
        _train_point(cfg, variant)


if __name__ == "__main__":
    run()
