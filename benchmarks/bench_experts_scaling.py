"""Paper Figure 6/7: step time vs number of experts at FIXED total slots.

Claim reproduced: Soft MoE's cost is flat in expert count (no sort/top-k),
while Tokens/Experts Choice step time grows with experts. Scaled down to
CPU (d=64, 256 tokens, 64 slots) — the *shape* of the curves is the claim,
not absolute time.
"""
from __future__ import annotations

import jax

from repro.configs.base import MoEConfig
from repro.core import moe_apply, moe_init

from .common import emit, time_fn

TOTAL_SLOTS = 64
D = 64


def run():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 256, D))
    rows = {}
    for variant in ("soft", "experts_choice", "tokens_choice"):
        for n_experts in (4, 8, 16, 32, 64):
            spe = max(TOTAL_SLOTS // n_experts, 1)
            cfg = MoEConfig(
                variant=variant, num_experts=n_experts, expert_d_ff=128,
                slots_per_expert=spe, top_k=1,
                capacity_factor=1.0, group_size=8,
            )
            params = moe_init(jax.random.PRNGKey(1), D, cfg)
            fn = jax.jit(lambda p, xx, _cfg=cfg: moe_apply(p, _cfg, xx)[0])
            us = time_fn(fn, params, x)
            rows[(variant, n_experts)] = us
            emit(f"fig6_step_time/{variant}/{n_experts}e", us,
                 f"slots={n_experts * spe}")
    # derived claim: soft flat (max/min < growth of tokens_choice)
    soft = [rows[("soft", n)] for n in (4, 8, 16, 32, 64)]
    tc = [rows[("tokens_choice", n)] for n in (4, 8, 16, 32, 64)]
    emit("fig6_soft_cost_ratio_64e_vs_4e", soft[-1],
         f"ratio={soft[-1] / soft[0]:.2f}")
    emit("fig6_tokens_choice_ratio_64e_vs_4e", tc[-1],
         f"ratio={tc[-1] / tc[0]:.2f}")
    # hardware-independent form of the claim: sort/top-k ops in the
    # compiled program (the accelerator-hostile part — paper §2.2 "Fast").
    # Soft MoE lowers to ZERO sorts at any expert count.
    for variant in ("soft", "tokens_choice", "experts_choice"):
        cfg = MoEConfig(variant=variant, num_experts=64, expert_d_ff=128,
                        slots_per_expert=1, top_k=1, group_size=8)
        params = moe_init(jax.random.PRNGKey(1), D, cfg)
        hlo = (
            jax.jit(lambda p, xx, _c=cfg: moe_apply(p, _c, xx)[0])
            .lower(params, x).compile().as_text()
        )
        n_sorts = hlo.count(" sort(")
        emit(f"fig6_hlo_sort_ops/{variant}/64e", 0.0, f"sorts={n_sorts}")


if __name__ == "__main__":
    run()
