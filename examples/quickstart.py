"""Quickstart: the Soft MoE layer in 40 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a Soft MoE layer (paper Algorithm 1+2), runs a forward pass, prints
the routing statistics the paper inspects in §5, and shows the `+soft`
config switch that drops the technique into any assigned architecture.
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.core import moe_init, soft_moe_weights
from repro.core.soft_moe import soft_moe_apply


def main():
    rng = jax.random.PRNGKey(0)
    d_model, tokens = 256, 196  # a ViT-S/16 sequence
    cfg = MoEConfig(variant="soft", num_experts=128, expert_d_ff=512,
                    slots_per_expert=1)
    params = moe_init(rng, d_model, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, d_model))

    y, metrics = soft_moe_apply(params, cfg, x)
    print(f"in  {x.shape} -> out {y.shape}")
    print(f"params: {sum(p.size for p in jax.tree_util.tree_leaves(params)):,}")

    # paper §5 model inspection: dispatch/combine weight distributions
    d_w, c_w = soft_moe_weights(x, params["phi"], params["scale"])
    per_token_total = d_w.sum(axis=(2, 3))[0]  # total weight each token sends
    print(f"token contribution to slots: min={float(per_token_total.min()):.3f} "
          f"max={float(per_token_total.max()):.3f} (no token dropped)")
    per_slot = d_w.sum(axis=1)[0]
    print(f"per-slot dispatch mass: {float(per_slot.min()):.3f}..."
          f"{float(per_slot.max()):.3f} (balanced by construction)")
    print(f"max combine weight: {float(metrics['max_combine']):.3f} "
          f"(<1.0: no softmax collapse — Algorithm 2 L2 norm)")

    # the technique as a first-class feature on an assigned arch
    cfg72 = get_config("llama3-8b+soft")
    print(f"\nllama3-8b+soft: moe variant={cfg72.moe.variant}, "
          f"{cfg72.moe.num_experts} experts in layers "
          f"{cfg72.moe_layer_indices()[:3]}...")


if __name__ == "__main__":
    main()
