"""End-to-end training driver: ~100M-param Soft-MoE ViT on the synthetic
image stream, with checkpointing/restart, straggler watchdog, and the full
trainer stack.

  PYTHONPATH=src python examples/train_vit_softmoe.py --steps 300

The default model is ViT-S/32-backbone with 8 experts in the second half
of blocks (~100M params, 49-token sequences — sized so a few hundred CPU
steps finish in minutes). ``--router`` switches the routing algorithm
(soft | tokens_choice | experts_choice | uniform | identity ...) for the
paper's Table-3-style comparisons.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import soft_moe_vit
from repro.data import SyntheticImages
from repro.models import build_model
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--router", default="soft")
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_vit_softmoe")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = soft_moe_vit("s", 32, args.experts, variant=args.router)
    cfg = dataclasses.replace(cfg, scan_layers=True, remat=False)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  (~{n_params/1e6:.0f}M params, "
          f"{cfg.frontend.num_embeds} tokens)")

    init, loss_fn, _ = build_model(cfg)
    data = SyntheticImages(
        num_patches=cfg.frontend.num_embeds,
        patch_dim=cfg.frontend.embed_dim,
        batch_size=args.batch, num_classes=1000,
    )
    tcfg = TrainerConfig(
        total_steps=args.steps, checkpoint_every=100,
        checkpoint_dir=args.ckpt_dir, log_every=10,
    )
    ocfg = OptimizerConfig(
        peak_lr=args.lr, warmup_steps=20, schedule="rsqrt",
        timescale=100.0, total_steps=args.steps,
        cooldown_steps=max(args.steps // 10, 1),
    )
    trainer = Trainer(tcfg, loss_fn, init, ocfg, data)
    trainer.run(jax.random.PRNGKey(0))
    hist = trainer.metrics_history
    if hist:
        print(f"\nloss: {hist[0]['total_loss']:.3f} -> "
              f"{hist[-1]['total_loss']:.3f} over {args.steps} steps; "
              f"acc {hist[-1].get('accuracy', 0):.3f}")


if __name__ == "__main__":
    main()
