"""Serve through the fault-tolerant asyncio front end.

  PYTHONPATH=src python examples/serve_async.py --requests 8
  PYTHONPATH=src python examples/serve_async.py --overload \
      --max-queue 4               # shed + retry under a burst
  PYTHONPATH=src python examples/serve_async.py --deadline-ms 50 \
      --cancel-after 3            # deadlines + mid-stream cancellation

Random weights (reduced config) — this demonstrates the serving-policy
machinery, not text quality: concurrent clients stream tokens through
``AsyncServer`` async generators while the engine batches them under
the hood; admission control sheds (with retry/backoff) when the bounded
queue or memory budget overflows; deadlines and client cancellations
free every row resource within one engine tick. The final metric
snapshot prints the counters the chaos harness and bench assert on."""
import argparse
import asyncio
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import AsyncServer, ServeEngine, ServerConfig, ShedError


async def client(srv, i, args):
    prompt = [1 + i, 2 + i, 3 + i]
    toks = []
    try:
        n = 0
        async for tok in srv.generate(
            prompt, max_new_tokens=args.max_new,
            deadline_s=(args.deadline_ms / 1e3 if args.deadline_ms else None),
        ):
            toks.append(tok)
            n += 1
            if args.cancel_after and n >= args.cancel_after:
                break  # abandoning the stream cancels the request
    except ShedError as e:
        print(f"[req {i}] shed ({e.reason})")
        return
    print(f"[req {i}] {toks}")


async def run(args):
    cfg = reduced(get_config(args.arch))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        cfg, params, batch_size=args.batch, max_len=64,
        backend="paged" if args.paged else "contiguous",
    )
    scfg = ServerConfig(max_queue=args.max_queue)
    if args.overload:
        # No retries and a tiny demand budget: the burst must shed.
        scfg.max_retries = 0
        scfg.max_demand_factor = 0.5
    async with AsyncServer(eng, scfg) as srv:
        await asyncio.gather(
            *(client(srv, i, args) for i in range(args.requests))
        )
        snap = srv.snapshot()
    print("\nmetrics:")
    for k, v in snap.items():
        print(f"  {k}: {v}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--overload", action="store_true",
                    help="shrink budgets so the burst load-sheds")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="per-request total deadline")
    ap.add_argument("--cancel-after", type=int, default=0,
                    help="clients abandon their stream after N tokens")
    asyncio.run(run(ap.parse_args()))


if __name__ == "__main__":
    main()
