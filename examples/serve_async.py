"""Serve through the fault-tolerant asyncio front end.

  PYTHONPATH=src python examples/serve_async.py --requests 8
  PYTHONPATH=src python examples/serve_async.py --overload \
      --max-queue 4               # shed + retry under a burst
  PYTHONPATH=src python examples/serve_async.py --deadline-ms 50 \
      --cancel-after 3            # deadlines + mid-stream cancellation
  PYTHONPATH=src python examples/serve_async.py --trace \
      --metrics-port 0            # span timelines + /metrics scrape

Random weights (reduced config) — this demonstrates the serving-policy
machinery, not text quality: concurrent clients stream tokens through
``AsyncServer`` async generators while the engine batches them under
the hood; admission control sheds (with retry/backoff) when the bounded
queue or memory budget overflows; deadlines and client cancellations
free every row resource within one engine tick. The final metric
snapshot prints the counters the chaos harness and bench assert on.

``--trace`` turns on the host-side span tracer + flight recorder
(serve/tracing.py) and prints each request's timeline plus a text
Gantt; ``--metrics-port`` binds the Prometheus /metrics + /healthz
endpoints (0 = pick an ephemeral port) and scrapes /metrics once at
the end; ``--telemetry`` compiles the model-interior telemetry
variants (serve/telemetry.py) and prints the per-layer routing-health
table plus the roofline-vs-measured program-efficiency gauges
(docs/observability.md — try ``--arch granite-moe-1b-a400m`` for the
MoE stats)."""
import argparse
import asyncio
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import (
    AsyncServer,
    ServeEngine,
    ServerConfig,
    ShedError,
    render_timeline,
    timeline,
)


async def client(srv, i, args, reqs):
    prompt = [1 + i, 2 + i, 3 + i]
    toks = []
    try:
        req = await srv.submit(
            prompt, max_new_tokens=args.max_new,
            deadline_s=(args.deadline_ms / 1e3 if args.deadline_ms else None),
        )
    except ShedError as e:
        print(f"[req {i}] shed ({e.reason})")
        return
    reqs.append(req)
    n = 0
    async for tok in srv.stream(req):
        toks.append(tok)
        n += 1
        if args.cancel_after and n >= args.cancel_after:
            break  # abandoning the stream cancels the request
    print(f"[req {i}] {toks}")


async def scrape(addr, path="/metrics"):
    """One GET against the server's observability listener."""
    reader, writer = await asyncio.open_connection(*addr)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: _\r\n\r\n".encode("ascii"))
    await writer.drain()
    raw = await reader.read()
    writer.close()
    return raw.decode("utf-8").split("\r\n\r\n", 1)[1]


def print_timelines(reqs):
    print("\nper-request timelines:")
    print(f"  {'req':>3} {'reason':<13} {'tok':>3} {'queue_ms':>8} "
          f"{'ttft_ms':>8} {'total_ms':>8} {'spans':>5}")
    for i, req in enumerate(reqs):
        tl = timeline(req)
        def ms(key):
            v = tl.get(key)
            return f"{v * 1e3:8.1f}" if v is not None else f"{'-':>8}"
        print(f"  {i:>3} {tl['finish_reason'] or '?':<13} "
              f"{tl['n_tokens']:>3} {ms('queue_s')} {ms('ttft_s')} "
              f"{ms('total_s')} {tl['n_spans']:>5}")
    print()
    print(render_timeline(reqs))


def print_telemetry(eng):
    """Per-layer routing-health table + program-efficiency gauges, from
    the device-side stats the telemetry program variants emit."""
    snap = eng.telemetry_snapshot()
    for phase in sorted(snap):
        flat = snap[phase]
        # moe_l<idx>_<stat> -> {layer: {stat: value}}
        layers = {}
        rest = {}
        for k, v in sorted(flat.items()):
            if k.startswith("moe_l"):
                lid, stat = k[len("moe_l"):].split("_", 1)
                layers.setdefault(int(lid), {})[stat] = v
            else:
                rest[k] = v
        print(f"\nmodel-interior telemetry [{phase}]:")
        if layers:
            stats = sorted({s for d in layers.values() for s in d})
            head = " ".join(f"{s[:16]:>16}" for s in stats)
            print(f"  {'layer':>5} {head}")
            for lid in sorted(layers):
                row = " ".join(f"{layers[lid].get(s, float('nan')):16.4g}"
                               for s in stats)
                print(f"  {lid:>5} {row}")
        for k, v in rest.items():
            print(f"  {k}: {v:.6g}")
    eff = eng.program_efficiency()
    if eff:
        print("\nroofline-vs-measured program efficiency "
              "(bound / measured mean wall; 1.0 = at the roofline "
              "bound on the target accelerator):")
        for program, ratio in sorted(eff.items()):
            print(f"  {program:>14}: {ratio:.3e}")


async def run(args):
    cfg = reduced(get_config(args.arch))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        cfg, params, batch_size=args.batch, max_len=64,
        backend="paged" if args.paged else "contiguous",
        trace=args.trace,
        flight_recorder=64 if args.trace else 0,
        telemetry=args.telemetry,
    )
    scfg = ServerConfig(max_queue=args.max_queue,
                        metrics_port=args.metrics_port)
    if args.overload:
        # No retries and a tiny demand budget: the burst must shed.
        scfg.max_retries = 0
        scfg.max_demand_factor = 0.5
    reqs = []
    async with AsyncServer(eng, scfg) as srv:
        if srv.metrics_addr is not None:
            host, port = srv.metrics_addr
            print(f"metrics: http://{host}:{port}/metrics  "
                  f"healthz: http://{host}:{port}/healthz")
        await asyncio.gather(
            *(client(srv, i, args, reqs) for i in range(args.requests))
        )
        prom = None
        if srv.metrics_addr is not None:
            prom = await scrape(srv.metrics_addr)
        snap = srv.snapshot()
    print("\nmetrics:")
    for k, v in snap.items():
        print(f"  {k}: {v}")
    if args.trace:
        print_timelines(reqs)
        if eng.recorder is not None and eng.recorder.ticks:
            print("\nflight recorder (last ticks):")
            print(eng.recorder.render(6))
    if args.telemetry:
        print_telemetry(eng)
    if prom is not None:
        head = prom.splitlines()[:12]
        print("\n/metrics scrape (first lines):")
        for line in head:
            print(f"  {line}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--overload", action="store_true",
                    help="shrink budgets so the burst load-sheds")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="per-request total deadline")
    ap.add_argument("--cancel-after", type=int, default=0,
                    help="clients abandon their stream after N tokens")
    ap.add_argument("--trace", action="store_true",
                    help="per-request span timelines + flight recorder")
    ap.add_argument("--telemetry", action="store_true",
                    help="model-interior telemetry: per-layer routing "
                         "health + program-efficiency gauges")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="bind /metrics + /healthz (0 = ephemeral port)")
    asyncio.run(run(ap.parse_args()))


if __name__ == "__main__":
    main()
