"""Paper §4: LIT-style contrastive learning — FROZEN Soft-MoE vision tower,
text tower trained from scratch against it (Zhai et al. 2022b).

  PYTHONPATH=src python examples/contrastive_lit.py --steps 200

Synthetic paired data: the "caption" tokens are a deterministic function
of the image's latent class, so a working tower pair drives InfoNCE loss
well below ln(batch)."""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced, soft_moe_vit
from repro.layers.common import lecun_init
from repro.models.vit import vit_features, vit_init
from repro.optim import OptimizerConfig, adamw_init, adamw_update


def text_tower_init(rng, vocab, d_model, d_out):
    r1, r2 = jax.random.split(rng)
    return {
        "embed": 0.02 * jax.random.normal(r1, (vocab, d_model)),
        "proj": lecun_init(r2, (d_model, d_out), fan_in=d_model),
    }


def text_tower_apply(params, tokens):
    x = params["embed"][tokens].mean(axis=1)  # bag of tokens
    return x @ params["proj"]


def info_nce(img_feats, txt_feats, temp=0.07):
    img = img_feats / jnp.linalg.norm(img_feats, axis=-1, keepdims=True)
    txt = txt_feats / jnp.linalg.norm(txt_feats, axis=-1, keepdims=True)
    logits = img @ txt.T / temp
    labels = jnp.arange(logits.shape[0])
    li = -jax.nn.log_softmax(logits, axis=1)[labels, labels].mean()
    lt = -jax.nn.log_softmax(logits, axis=0)[labels, labels].mean()
    return 0.5 * (li + lt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    vocab, n_classes = 512, 64
    cfg = reduced(soft_moe_vit("s", 16, 8))
    rng = jax.random.PRNGKey(0)
    vision_params = vit_init(rng, cfg, num_classes=n_classes)  # frozen
    d_feat = cfg.d_model
    text_params = text_tower_init(jax.random.PRNGKey(1), vocab, 64, d_feat)
    opt = adamw_init(text_params)
    ocfg = OptimizerConfig(peak_lr=3e-3, schedule="constant",
                           warmup_steps=10, total_steps=10**9,
                           cooldown_steps=1)

    rng_cls = np.random.default_rng(0)
    class_protos = rng_cls.standard_normal(
        (n_classes, cfg.frontend.num_embeds, cfg.frontend.embed_dim)
    ).astype(np.float32)
    class_tokens = rng_cls.integers(1, vocab, size=(n_classes, 8))

    @jax.jit
    def step(text_params, opt, images, tokens):
        img_feats = vit_features(vision_params, cfg, images)  # frozen

        def loss_fn(tp):
            return info_nce(img_feats, text_tower_apply(tp, tokens))

        loss, grads = jax.value_and_grad(loss_fn)(text_params)
        text_params, opt, _ = adamw_update(grads, opt, text_params, ocfg)
        return text_params, opt, loss

    losses = []
    for s in range(args.steps):
        cls = rng_cls.choice(n_classes, size=args.batch, replace=False)
        images = jnp.asarray(
            class_protos[cls]
            + 0.3 * rng_cls.standard_normal(class_protos[cls].shape)
        )
        tokens = jnp.asarray(class_tokens[cls])
        text_params, opt, loss = step(text_params, opt, images, tokens)
        losses.append(float(loss))
        if (s + 1) % 25 == 0:
            print(f"step {s+1}: InfoNCE {losses[-1]:.4f} "
                  f"(chance={np.log(args.batch):.3f})")
    assert losses[-1] < losses[0], "contrastive training failed to improve"
    print(f"\nfrozen Soft-MoE tower + trained text tower: "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
