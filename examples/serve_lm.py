"""Serve a small LM through the continuous-batching engine.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --requests 6
  PYTHONPATH=src python examples/serve_lm.py --paged --block-size 16 \
      --shared-prefix 32          # paged backend + radix prefix cache
  PYTHONPATH=src python examples/serve_lm.py --paged --spec-k 4 \
      --max-new 32                # n-gram speculative decoding

Uses the reduced config (random weights — this demonstrates the serving
machinery): requests with mixed prompt lengths, token budgets, and
per-request sampling params stream through the slot pool; chunked prefill
interleaves with decode; rows retire the step they finish and the next
queued request takes the slot immediately. Tokens stream via the
``Request.on_token`` callback as they are sampled."""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import Request, SamplingParams, ServeEngine, SpecConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stream", action="store_true",
                    help="print each token as it is sampled")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged block-manager backend")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged backend)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="total pool blocks (default: contiguous parity)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="radix-tree prefix sharing (paged; default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every request (exercises the radix cache)")
    ap.add_argument("--no-kernel", dest="use_kernel", action="store_false",
                    default=True,
                    help="paged decode via the jnp row-view gather oracle "
                         "instead of the Pallas paged-attention kernel")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft k tokens per verify "
                         "step via n-gram prompt lookup (0 = off)")
    ap.add_argument("--cache-generated", action="store_true",
                    help="also publish retired requests' generated tokens "
                         "into the radix prefix cache (paged backend)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}, vocab={cfg.vocab_size})")
    params = lm_init(jax.random.PRNGKey(0), cfg)
    kw = {}
    if args.paged:
        kw = dict(backend="paged", block_size=args.block_size,
                  num_blocks=args.num_blocks,
                  prefix_cache=args.prefix_cache,
                  use_kernel=args.use_kernel,
                  cache_generated=args.cache_generated)
        print(f"paged backend: block_size={args.block_size} "
              f"prefix_cache={args.prefix_cache} "
              f"cache_generated={args.cache_generated} "
              f"decode={'kernel' if args.use_kernel else 'gather'}")
    if args.spec_k > 0:
        kw["spec"] = SpecConfig(k=args.spec_k)
        print(f"speculative decoding: k={args.spec_k} (n-gram self-draft)")
    eng = ServeEngine(cfg, params, batch_size=args.batch, max_len=128, **kw)

    def stream(req, tok):
        print(f"  req[{req.sampling.seed}] += {tok}")

    rng = jax.random.PRNGKey(1)
    shared = list(
        jax.random.randint(jax.random.PRNGKey(2), (args.shared_prefix,),
                           1, cfg.vocab_size).tolist()
    )
    reqs = []
    for i in range(args.requests):
        rng, r = jax.random.split(rng)
        prompt = shared + list(
            jax.random.randint(r, (4 + i % 5,), 1, cfg.vocab_size).tolist()
        )
        req = Request(
            prompt=prompt,
            max_new_tokens=args.max_new,
            sampling=SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, seed=i,
            ),
            on_token=stream if args.stream else None,
        )
        reqs.append(req)
        eng.submit(req)

    t0 = time.perf_counter()
    steps = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    for i, r in enumerate(reqs):
        # finish_reason: "cache_ceiling" marks a TRUNCATED response (the
        # request hit max_len), distinct from a normal eos/length stop.
        print(f"req {i}: prompt[{len(r.prompt)}] -> {r.out} "
              f"[{r.finish_reason}]")
    truncated = sum(r.finish_reason == "cache_ceiling" for r in reqs)
    print(f"{args.requests} requests ({truncated} truncated at the cache "
          f"ceiling), {steps} decode steps, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")
    stats = eng.spec_stats()
    if stats is not None:
        print(f"spec decode: acceptance {stats['acceptance_rate']:.2f} "
              f"({stats['accepted']}/{stats['drafted']} drafts), "
              f"{stats['calls_per_token']:.2f} batched model calls/token")
    if args.paged:
        print(f"peak cache {eng.peak_cache_bytes()/1e6:.2f}MB "
              f"(live high-water {eng.backend.live_block_hw} blocks; "
              f"pool high-water {eng.backend.mgr.high_water})")
        if eng.backend.prefix is not None:
            print(f"prefix cache: {eng.backend.prefix.hits} block hits, "
                  f"{eng.backend.prefix.misses} cold lookups")


if __name__ == "__main__":
    main()
