"""Serve a small LM with batched requests through the engine.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --requests 6

Uses the reduced config (random weights — this demonstrates the serving
machinery: prefill -> batched lockstep decode over the KV-cache pool,
wave admission, greedy/temperature sampling)."""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced
from repro.models import lm_init
from repro.serve import Request, ServeEngine, sample_temperature


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}, vocab={cfg.vocab_size})")
    params = lm_init(jax.random.PRNGKey(0), cfg)
    sampler = (
        (lambda r, l: sample_temperature(r, l, args.temperature))
        if args.temperature > 0 else None
    )
    kw = {"sampler": sampler} if sampler else {}
    eng = ServeEngine(cfg, params, batch_size=args.batch, max_len=128, **kw)

    rng = jax.random.PRNGKey(1)
    for i in range(args.requests):
        rng, r = jax.random.split(rng)
        prompt = list(
            jax.random.randint(r, (4 + i % 5,), 1, cfg.vocab_size)
            .tolist()
        )
        eng.submit(Request(prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    steps = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = args.requests * args.max_new
    print(f"{args.requests} requests, {steps} decode steps, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
